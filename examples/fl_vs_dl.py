"""FL emulation vs DL (paper Fig. 1: 'to emulate FL, a node can be
modified to coordinate the training, shown as the FL server').

Same dataset, same non-IID partition, same optimizer — one run with the
FederatedRunner (central server, client subset per round) and one with the
DecentralizedRunner (5-regular gossip, no server).

    PYTHONPATH=src python examples/fl_vs_dl.py --rounds 40
"""
import argparse

from repro.core import DLConfig, DecentralizedRunner, FLConfig, FederatedRunner
from repro.data import NodeBatcher, make_dataset, sharding_partition
from repro.models.api import cross_entropy
from repro.models.mlp import mlp_apply, mlp_init
from repro.optim import make_optimizer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--nodes", type=int, default=16)
    args = ap.parse_args()

    ds = make_dataset("cifar10", n_train=1024, n_test=512, sigma=4.0)
    parts = sharding_partition(ds.train_y, args.nodes, 2, seed=0)
    batcher = NodeBatcher(ds.train_x, ds.train_y, parts, 8, seed=0)
    loss_fn = lambda p, x, y: cross_entropy(mlp_apply(p, x), y)
    acc_fn = lambda p, x, y: (mlp_apply(p, x).argmax(-1) == y).mean()
    init = lambda k: mlp_init(k, hidden=64)

    fl = FLConfig(n_clients=args.nodes, clients_per_round=args.nodes // 2,
                  local_steps=4, rounds=args.rounds, eval_every=args.rounds // 4)
    r_fl = FederatedRunner(fl, init, loss_fn, acc_fn, make_optimizer("sgd", 0.05), batcher)
    h_fl = r_fl.run(log=False)

    dl = DLConfig(n_nodes=args.nodes, topology="regular", degree=5,
                  local_steps=4, rounds=args.rounds, eval_every=args.rounds // 4)
    r_dl = DecentralizedRunner(dl, init, loss_fn, acc_fn, make_optimizer("sgd", 0.05), batcher)
    h_dl = r_dl.run(log=False)

    print(f"{'round':>6s} {'FedAvg':>8s} {'D-PSGD':>8s}")
    fl_by_round = {h['round']: h['acc'] for h in h_fl}
    dl_by_round = {h['round']: h['acc_mean'] for h in h_dl}
    for r in sorted(set(fl_by_round) | set(dl_by_round)):
        print(f"{r:6d} {fl_by_round.get(r, float('nan')):8.4f} "
              f"{dl_by_round.get(r, float('nan')):8.4f}")
    print(f"\nD-PSGD bytes/node: {r_dl.bytes_sent/1e6:.1f} MB "
          f"(FL server would carry {args.nodes//2}x that inbound per round)")


if __name__ == "__main__":
    main()
