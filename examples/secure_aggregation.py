"""Secure aggregation (paper §3.4): pairwise cancellable masks on a
regular graph — same accuracy trajectory as plain D-PSGD, individual
models hidden, ~3% byte overhead.

    PYTHONPATH=src python examples/secure_aggregation.py --rounds 30
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DLConfig, DecentralizedRunner, SecureAggregation, build_graph
from repro.data import NodeBatcher, make_dataset, sharding_partition
from repro.models.api import cross_entropy
from repro.models.mlp import mlp_apply, mlp_init
from repro.optim import make_optimizer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=30)
    args = ap.parse_args()

    ds = make_dataset("cifar10", n_train=8192, n_test=512)
    parts = sharding_partition(ds.train_y, 16, 2, seed=0)
    batcher = NodeBatcher(ds.train_x, ds.train_y, parts, 8, seed=0)
    loss_fn = lambda p, x, y: cross_entropy(mlp_apply(p, x), y)
    acc_fn = lambda p, x, y: (mlp_apply(p, x).argmax(-1) == y).mean()

    results = {}
    for name, secure in (("d-psgd", False), ("secure-agg", True)):
        dl = DLConfig(n_nodes=16, topology="regular", degree=4, secure=secure,
                      rounds=args.rounds, eval_every=args.rounds - 1, local_steps=2)
        r = DecentralizedRunner(dl, lambda k: mlp_init(k, hidden=128), loss_fn,
                                acc_fn, make_optimizer("sgd", 0.05), batcher)
        hist = r.run(log=False)
        results[name] = (hist[-1]["acc_mean"], r.bytes_sent)
        print(f"{name:12s} acc {hist[-1]['acc_mean']:.4f}  MB/node {r.bytes_sent/1e6:.1f}")

    overhead = results["secure-agg"][1] / results["d-psgd"][1] - 1
    print(f"\ncommunication overhead: {overhead:.1%} (paper: ~3%)")

    # show that an individual masked message is unreadable while the
    # aggregate is exact
    g = build_graph(DLConfig(n_nodes=8, topology="regular", degree=4))
    X = jax.random.normal(jax.random.key(0), (8, 1000))
    W = jnp.asarray(g.metropolis_hastings(), jnp.float32)
    s = SecureAggregation(g.adj, mask_bound=5.0)
    msgs = s.messages(X, jax.random.key(1), 0)
    (i, r0), m = next(iter(msgs.items()))
    rel = float(jnp.linalg.norm(m - X[i]) / jnp.linalg.norm(X[i]))
    agg, _, _ = s.round(X, W, (), jax.random.key(1), degree=4.0, rnd=0)
    err = float(jnp.max(jnp.abs(agg - W @ X)))
    print(f"masked message vs raw model distance: {rel:.1f}x norm (unreadable)")
    print(f"aggregate vs plain MH aggregate max err: {err:.2e} (masks cancel)")


if __name__ == "__main__":
    main()
