"""Fault-tolerant gossip gate: convergence under message-level fault
injection (core.faults.FaultPlan) must cost bounded *simulated* time.

Protocol (the tentpole acceptance gate):

1. A fault-free consensus run (default N=1024, 6-regular, LAN link model)
   defines the target: the accuracy level at 90%% of the clean run's total
   improvement, and ``T0`` = the simulated time of the first eval at or
   above it.
2. The faulty run — identical config plus ``FaultPlan(msg_loss=0.1)`` —
   gets up to 2x the rounds; ``T1`` is the simulated time of its first
   eval at or above the same target.  Lost messages renormalize the mixing
   operand (rows stay stochastic), so gossip under 10%% loss converges
   slower, not wrong.
3. **Gate**: median ``T1 / T0`` over ``--repeats`` seeds <= 1.5 — i.e.
   10%% message loss costs at most 50%% extra simulated wall-clock to the
   same accuracy.  Per-seed ratios, fault counters (with the
   ``injected == detected + survived`` conservation check), and the gate
   verdict are recorded to results/bench_faults.json.

    PYTHONPATH=src:. python benchmarks/bench_faults.py
    PYTHONPATH=src:. python benchmarks/bench_faults.py --smoke   # CI-sized
"""
from __future__ import annotations

import argparse
import statistics

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DLConfig, FaultPlan, RoundEngine
from repro.data import NodeBatcher, make_dataset, sharding_partition
from repro.optim import make_optimizer

from benchmarks.common import save_results

MSG_LOSS = 0.10
GATE_MAX_SLOWDOWN = 1.5
TARGET_FRAC = 0.9  # target = 90% of the clean run's total improvement


def _consensus_engine(n: int, rounds: int, degree: int, seed: int,
                      faults: FaultPlan | None = None,
                      eval_every: int = 4) -> RoundEngine:
    ds = make_dataset("cifar10", n_train=2048, n_test=64, shape=(2, 2, 1),
                      sigma=2.0)
    parts = sharding_partition(ds.train_y, n, 2, seed=0)
    batcher = NodeBatcher(ds.train_x, ds.train_y, parts, batch_size=4, seed=0)

    def loss(p, x, y):
        t = x.reshape(x.shape[0], -1).mean(0)
        return jnp.mean((p["w"].reshape(-1, t.shape[0]) - t) ** 2)

    dl = DLConfig(n_nodes=n, topology="regular", degree=degree, rounds=rounds,
                  eval_every=eval_every, local_steps=1, batch_size=4,
                  chunk_rounds=min(8, eval_every), network="lan",
                  compute_time_s=0.01, seed=seed, faults=faults)
    return RoundEngine(dl, lambda k: {"w": jax.random.normal(k, (64,))}, loss,
                       lambda p, x, y: -loss(p, x, y),
                       make_optimizer("sgd", 0.05), batcher)


def _time_to_target(history, target):
    """Simulated time of the first eval with acc_mean >= target (None if
    the run never gets there)."""
    for rec in history:
        if rec["acc_mean"] >= target:
            return rec["sim_time_s"]
    return None


def _fault_record(eng):
    t = {k: float(v) for k, v in eng.scheduler._fault_totals.items()}
    conserved = abs(
        t["faults_injected"] - t["faults_detected"] - t["faults_survived"]
    ) < 1e-6
    assert conserved, f"fault counter conservation violated: {t}"
    t["conservation_ok"] = conserved
    return t


def run_gate(n: int, rounds: int, degree: int, repeats: int, log: bool = True):
    recs = []
    ratios = []
    for rep in range(repeats):
        seed = 3 + rep
        clean = _consensus_engine(n, rounds, degree, seed)
        clean.run(log=False)
        accs = [r["acc_mean"] for r in clean.history]
        target = accs[0] + TARGET_FRAC * (accs[-1] - accs[0])
        t0 = _time_to_target(clean.history, target)
        plan = FaultPlan(msg_loss=MSG_LOSS, seed=seed)
        faulty = _consensus_engine(n, 2 * rounds, degree, seed, faults=plan)
        faulty.run(log=False)
        t1 = _time_to_target(faulty.history, target)
        converged = t0 is not None and t1 is not None
        ratio = (t1 / t0) if converged else float("inf")
        ratios.append(ratio)
        fr = _fault_record(faulty)
        recs.append({
            "name": f"N{n}-loss{MSG_LOSS:.2f}-seed{seed}",
            "n_nodes": n, "degree": degree, "rounds": rounds,
            "msg_loss": MSG_LOSS, "target_acc": target,
            "clean_time_to_target_s": t0, "faulty_time_to_target_s": t1,
            "slowdown": ratio, **fr,
        })
        if log:
            print(f"  N={n} seed{seed}: clean {t0 if t0 is None else round(t0, 3)}s "
                  f"-> faulty {t1 if t1 is None else round(t1, 3)}s "
                  f"({ratio:.2f}x), injected {fr['faults_injected']:.0f}",
                  flush=True)
    med = statistics.median(ratios)
    gate_pass = bool(np.isfinite(med) and med <= GATE_MAX_SLOWDOWN)
    recs.append({
        "name": f"N{n}-fault-convergence-gate",
        "median_slowdown": med if np.isfinite(med) else None,
        "gate_max_slowdown": GATE_MAX_SLOWDOWN,
        "gate_pass": gate_pass,
    })
    if log:
        print(f"  N={n} median slowdown under {MSG_LOSS:.0%} loss: "
              f"{med:.2f}x (gate: <= {GATE_MAX_SLOWDOWN}x) "
              f"{'PASS' if gate_pass else 'FAIL'}", flush=True)
    return recs, gate_pass


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=1024)
    ap.add_argument("--degree", type=int, default=6)
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: N=64, one repeat, same gate")
    args = ap.parse_args()
    if args.smoke:
        args.nodes, args.rounds, args.repeats = 64, 24, 1
    recs, ok = run_gate(args.nodes, args.rounds, args.degree, args.repeats)
    path = save_results("bench_faults", recs)
    print(f"\nresults -> {path}")
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
