"""Roofline table: aggregates the dry-run JSONs (results/dryrun_sp|mp) into
the EXPERIMENTS.md §Roofline table — one row per (arch x shape x mesh):
three terms, dominant bottleneck, MODEL_FLOPS/HLO ratio."""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(dirs):
    rows = []
    for d in dirs:
        for f in sorted(glob.glob(os.path.join(d, "*.json"))):
            with open(f) as fh:
                rows.append(json.load(fh))
    return rows


def table(rows, fmt="md"):
    header = (
        "| arch | shape | mesh | compute s | mem s (unfused) | mem s (fused) "
        "| collective s | bottleneck | useful FLOPs | coll GB/dev |"
    )
    sep = "|---|---|---|---|---|---|---|---|---|---|"
    lines = [header, sep]
    for r in rows:
        if r.get("status") == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | — | "
                f"SKIP: {r['reason'][:46]} | — | — |"
            )
            continue
        ro = r["roofline"]
        fused = ro.get("t_memory_fused")
        fused_s = f"{fused:.4f}" if fused is not None else "—"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {ro['t_compute']:.4f} | {ro['t_memory']:.4f} | {fused_s} "
            f"| {ro['t_collective']:.4f} "
            f"| **{ro['bottleneck']}** | {ro['useful_flops_ratio']:.1%} "
            f"| {ro['coll_bytes_dev']/1e9:.2f} |"
        )
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dirs", nargs="*", default=["results/dryrun_sp", "results/dryrun_mp"])
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args()
    rows = load(args.dirs)
    if args.csv:
        print("arch,shape,mesh,t_compute,t_memory,t_collective,bottleneck,useful,coll_gb")
        for r in rows:
            if r.get("status") == "skipped":
                print(f"{r['arch']},{r['shape']},{r['mesh']},,,,skipped:{r['reason'][:30]},,")
            else:
                ro = r["roofline"]
                print(
                    f"{r['arch']},{r['shape']},{r['mesh']},{ro['t_compute']:.5f},"
                    f"{ro['t_memory']:.5f},{ro['t_collective']:.5f},{ro['bottleneck']},"
                    f"{ro['useful_flops_ratio']:.3f},{ro['coll_bytes_dev']/1e9:.2f}"
                )
    else:
        print(table(rows))


if __name__ == "__main__":
    main()
