"""Churn & heterogeneous-time realism (the paper's missing scenario axes):
accuracy / bytes / simulated wall-clock across per-round participation
levels, iid vs machine-correlated failures, straggler compute-time
distributions, and the sync-vs-local execution-semantics split — all
inside the RoundEngine's scanned chunks.

Sections (all recorded to results/bench_churn.json via
benchmarks/common.save_results):

1. *Participation sweep* — iid churn at p in {1.0, 0.9, 0.7, 0.5}: bytes
   drop roughly linearly while accuracy degrades slowly (gossip averaging
   is robust to moderate churn).  A down node does no local step, is cut
   out of the round's mixing operand, and freezes its params/opt/sharing
   state until it rejoins — rejoin-with-stale-model, never reweight-away.
2. *Correlated failures* — ``churn_machines=M`` drops whole machines
   (round-robin node->machine mapping) instead of iid nodes: the same
   expected participation with bursty, spatially-correlated outages.
3. *Stragglers x semantics* — a seeded 10%% of nodes at 10x the base
   compute time (``straggler_factor``/``straggler_frac``): the
   synchronous barrier pays the straggler every round, while
   ``semantics='local'`` (identical trajectories, per-node
   neighborhood-barrier clocks) shows the median node finishing far
   earlier.
4. *Timed gate* — rounds/s of the churned engine vs full participation,
   min/median/mean over interleaved repeats, **gate on the median** like
   bench_engine: the participation-mask machinery rides the compiled scan,
   so churn must cost < 2x throughput (median ratio >= 0.5).

    PYTHONPATH=src:. python benchmarks/bench_churn.py --rounds 40
"""
from __future__ import annotations

import argparse
import statistics
import time

import jax
import jax.numpy as jnp

from repro.core import DLConfig, RoundEngine
from repro.data import NodeBatcher, make_dataset, sharding_partition
from repro.optim import make_optimizer

from benchmarks.common import dl_experiment, save_results


def run(nodes: int = 32, rounds: int = 40, model: str = "mlp", seeds: int = 1,
        log: bool = True):
    """Accuracy/bytes/sim-time sections (1-3): everything through
    RoundEngine's scanned chunks via the shared dl_experiment harness."""
    recs = []
    base = dict(n_nodes=nodes, topology="regular", degree=5, rounds=rounds,
                eval_every=max(rounds // 4, 1), local_steps=2, batch_size=8,
                network="lan")
    # 1. iid participation sweep
    for p in (1.0, 0.9, 0.7, 0.5):
        dl = DLConfig(participation=p, **base)
        recs.append(
            dl_experiment(f"participation-{p:.1f}", dl, model=model, width=8,
                          seeds=seeds, log=log)
        )
    # 2. machine-correlated failures at matched expected participation
    dl = DLConfig(participation=0.7, churn_machines=8, **base)
    recs.append(
        dl_experiment("machine-churn-0.7x8", dl, model=model, width=8,
                      seeds=seeds, log=log)
    )
    # 3. straggler compute distribution, sync barrier vs local clocks
    #    (same trajectories — only the time semantics differ)
    for sem in ("sync", "local"):
        dl = DLConfig(compute_time_s=0.05, straggler_factor=10.0,
                      straggler_frac=0.1, semantics=sem, **base)
        rec = dl_experiment(f"straggler-10x-{sem}", dl, model=model, width=8,
                            seeds=seeds, log=log)
        rec.update({k: v for k, v in rec["history"][-1].items()
                    if k.startswith("vclock")})
        recs.append(rec)
    sync_t = next(r for r in recs if r["name"] == "straggler-10x-sync")["sim_time_s"]
    local = next(r for r in recs if r["name"] == "straggler-10x-local")
    if log:
        print(f"  straggler-10x sim time: sync {sync_t:.1f}s, local max "
              f"{local['sim_time_s']:.1f}s, local median node "
              f"{local.get('vclock_median_s', float('nan')):.1f}s", flush=True)
    return recs


# ---------------------------------------------------------------------------
# timed section: the scan must absorb churn masks ~for free
# ---------------------------------------------------------------------------

def _consensus_engine(n: int, rounds: int, participation: float,
                      chunk: int = 32) -> RoundEngine:
    ds = make_dataset("cifar10", n_train=1024, n_test=64, shape=(2, 2, 1),
                      sigma=2.0)
    parts = sharding_partition(ds.train_y, n, 2, seed=0)
    batcher = NodeBatcher(ds.train_x, ds.train_y, parts, batch_size=4, seed=0)

    def loss(p, x, y):
        t = x.reshape(x.shape[0], -1).mean(0)
        return jnp.mean((p["w"].reshape(-1, t.shape[0]) - t) ** 2)

    dl = DLConfig(n_nodes=n, topology="regular", degree=5, rounds=rounds,
                  eval_every=10**9, local_steps=1, batch_size=4,
                  chunk_rounds=chunk, participation=participation)
    return RoundEngine(dl, lambda k: {"w": jax.random.normal(k, (64,))}, loss,
                       lambda p, x, y: -loss(p, x, y),
                       make_optimizer("sgd", 0.05), batcher)


def run_timed(n: int = 128, rounds: int = 32, repeats: int = 3,
              log: bool = True):
    """Section 4: churned vs full-participation rounds/s (min/median/mean,
    interleaved repeats, gate on the median ratio >= 0.5)."""
    recs = []
    if rounds <= 0:
        return recs
    engines = {
        "full": _consensus_engine(n, rounds, participation=1.0),
        "churn0.5": _consensus_engine(n, rounds, participation=0.5),
    }
    for eng in engines.values():  # warm-up compiles every scan length
        eng.run(rounds=rounds, log=False)
    samples = {case: [] for case in engines}
    for _ in range(repeats):
        for case, eng in engines.items():
            t0 = time.time()
            eng.run(rounds=rounds, log=False)
            samples[case].append(rounds / (time.time() - t0))
    rps = {}
    for case, s in samples.items():
        rps[case] = statistics.median(s)
        recs.append({
            "name": f"N{n}-timed-{case}", "n_nodes": n, "rounds": rounds,
            "rounds_per_s": rps[case], "rounds_per_s_min": min(s),
            "rounds_per_s_mean": sum(s) / len(s),
        })
        if log:
            print(f"  N={n} {case:9s} {rps[case]:8.1f} rounds/s "
                  f"(min {min(s):.1f})", flush=True)
    ratio = rps["churn0.5"] / rps["full"]
    recs.append({
        "name": f"N{n}-churn-throughput-gate", "churn_speed_ratio": ratio,
        "gate_min_ratio": 0.5, "gate_pass": bool(ratio >= 0.5),
    })
    if log:
        print(f"  N={n} churned/full rounds/s (median): {ratio:.2f}x "
              f"(gate: >= 0.5x)", flush=True)
    return recs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=32)
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--seeds", type=int, default=1)
    ap.add_argument("--timed-nodes", type=int, default=128)
    ap.add_argument("--timed-rounds", type=int, default=32,
                    help="rounds for the churn-throughput gate; 0 skips it")
    ap.add_argument("--timed-repeats", type=int, default=3)
    args = ap.parse_args()
    recs = []
    if args.rounds > 0:
        recs += run(args.nodes, args.rounds, seeds=args.seeds)
    recs += run_timed(args.timed_nodes, args.timed_rounds, args.timed_repeats)
    save_results("bench_churn", recs)
    print("\nname,acc|rounds_per_s,bytes_per_node_MB,sim_time_s")
    for r in recs:
        if "acc_mean" in r:
            print(f"{r['name']},{r['acc_mean']:.4f},"
                  f"{r['bytes_per_node']/1e6:.1f},{r['sim_time_s']:.2f}")
        elif "rounds_per_s" in r:
            print(f"{r['name']},{r['rounds_per_s']:.1f},,")


if __name__ == "__main__":
    main()
