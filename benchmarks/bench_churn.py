"""Participation churn (the paper's missing scenario axis): accuracy /
bytes / simulated wall-clock vs per-round node participation probability.

A node that is down for a round does no local step and is removed from the
mixing matrix for that round (sharing.participation_reweight); everything
runs inside the engine's scanned chunks.  Expected shape: communication
drops roughly linearly with participation while accuracy degrades slowly —
gossip averaging is robust to moderate churn.

    PYTHONPATH=src:. python benchmarks/bench_churn.py --rounds 40
"""
from __future__ import annotations

import argparse

from repro.core import DLConfig

from benchmarks.common import dl_experiment, save_results


def run(nodes: int = 32, rounds: int = 40, model: str = "mlp", seeds: int = 1,
        log: bool = True):
    recs = []
    for p in (1.0, 0.9, 0.7, 0.5):
        dl = DLConfig(n_nodes=nodes, topology="regular", degree=5, rounds=rounds,
                      eval_every=max(rounds // 4, 1), local_steps=2, batch_size=8,
                      participation=p, network="lan")
        recs.append(
            dl_experiment(f"participation-{p:.1f}", dl, model=model, width=8,
                          seeds=seeds, log=log)
        )
    save_results("bench_churn", recs)
    return recs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=32)
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--seeds", type=int, default=1)
    args = ap.parse_args()
    recs = run(args.nodes, args.rounds, seeds=args.seeds)
    print("\nname,acc,bytes_per_node_MB,sim_time_s")
    for r in recs:
        print(f"{r['name']},{r['acc_mean']:.4f},{r['bytes_per_node']/1e6:.1f},"
              f"{r['sim_time_s']:.2f}")


if __name__ == "__main__":
    main()
