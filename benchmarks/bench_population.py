"""Population-scale async engine benchmark — the cohort gather/scatter
gate (ISSUE 6 acceptance):

1. An N=100k asynchronous run completes at ``cohort_capacity``-bounded
   memory (hot working set O(C·(d+1)·P), independent of N — asserted
   against the scheduler's analytic ``memory_model`` at two population
   sizes and recorded empirically via live device-buffer bytes).
2. Per-active-node event throughput of the cohort path at N=100k is
   within 2x of the dense-oracle cohort rate at N=1024 (recorded median
   over interleaved repeats).

The workload is a small per-node MLP (the paper's model family at toy
scale) trained by per-event local SGD — a fired event pays realistic
gradient FLOPs, so the gate compares end-to-end per-event cost, not just
bookkeeping.  Both runs use homogeneous event times and
``async_slice_s=0`` so every step fires a full cohort: the dense N=1024
baseline fires 1024 events per step over an O(N·(d+1)·P) working set;
the cohort N=100k run fires C events per step over O(C·(d+1)·P) plus
O(N) selection/scatter.

Records land in ``results/bench_population.json`` (uploaded by CI); the
shared ``save_results`` appends live-device-bytes + host-RSS capture.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import memory_snapshot, save_results
from repro.core import DLConfig, RoundEngine
from repro.data import NodeBatcher
from repro.optim import make_optimizer

SHAPE = (4, 4, 1)
N_CLASSES = 2


def _make_init(hidden: int):
    feat = int(np.prod(SHAPE))

    def init(k):
        k1, k2 = jax.random.split(k)
        return {
            "w1": jax.random.normal(k1, (feat, hidden)) / np.sqrt(feat),
            "b1": jnp.zeros((hidden,)),
            "w2": jax.random.normal(k2, (hidden, N_CLASSES)) / np.sqrt(hidden),
            "b2": jnp.zeros((N_CLASSES,)),
        }

    return init


def _apply(p, x):
    h = jnp.tanh(x.reshape(x.shape[0], -1) @ p["w1"] + p["b1"])
    return h @ p["w2"] + p["b2"]


def _loss(p, x, y):
    logp = jax.nn.log_softmax(_apply(p, x))
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def _acc(p, x, y):
    return (_apply(p, x).argmax(-1) == y).mean()


def _engine(n_nodes: int, cohort: int, *, hidden: int, chunk: int,
            batch: int = 4, degree: int = 4, seed: int = 0) -> RoundEngine:
    """Async MLP-per-node engine: each fired event runs one local SGD
    step of a (feat -> hidden -> classes) MLP and a neighborhood gossip,
    with homogeneous ms-scale event times and no network model."""
    rng = np.random.default_rng(seed)
    n_train = max(n_nodes, 256)
    x = rng.normal(size=(n_train, *SHAPE)).astype(np.float32)
    y = rng.integers(0, N_CLASSES, size=(n_train,)).astype(np.int32)
    parts = np.array_split(np.arange(n_train), n_nodes)
    dl = DLConfig(
        n_nodes=n_nodes, topology="regular", degree=degree, sharing="full",
        semantics="async", async_gossip="neighborhood", async_slice_s=0.0,
        chunk_rounds=chunk, eval_every=10_000, batch_size=batch,
        compute_time_s=1e-3, cohort_capacity=cohort, seed=seed,
        batch_keying="node",
    )
    batcher = NodeBatcher(x, y, parts, dl.batch_size, seed=seed)
    return RoundEngine(dl, _make_init(hidden), _loss, _acc,
                       make_optimizer("sgd", 0.05), batcher)


def _events_per_sec(eng: RoundEngine, steps: int) -> float:
    """Fired events per wall second over ``steps`` scanned event steps
    (post-warmup; the caller interleaves repeats)."""
    sched = eng.scheduler
    start = getattr(eng, "_bench_round", 0)
    before = sched._fired_total
    t0 = time.perf_counter()
    done = 0
    while done < steps:
        r = min(eng.chunk, steps - done)
        sched.run_span(start + done, r)
        done += r
    jax.block_until_ready(eng.params)
    dt = time.perf_counter() - t0
    eng._bench_round = start + done
    return (sched._fired_total - before) / max(dt, 1e-9)


def run_population(dense_nodes: int, pop_nodes: int, cohort: int,
                   hidden: int, steps: int, repeats: int, chunk: int,
                   batch: int):
    recs = []
    print(f"[population] dense N={dense_nodes} oracle vs "
          f"cohort N={pop_nodes} C={cohort} (hidden={hidden}, B={batch}, "
          f"{steps} steps, {repeats} repeats)", flush=True)
    t0 = time.time()
    dense = _engine(dense_nodes, 0, hidden=hidden, chunk=chunk, batch=batch)
    coh = _engine(pop_nodes, cohort, hidden=hidden, chunk=chunk, batch=batch)
    print(f"  engines built in {time.time() - t0:.1f}s", flush=True)
    # warmup: compile both full-length chunk programs (a shorter span
    # would compile a different scan length and leak the timed repeats'
    # first-call compile into the measurement)
    dense.scheduler.run_span(0, chunk)
    coh.scheduler.run_span(0, chunk)
    dense._bench_round = coh._bench_round = chunk
    dense_rates, cohort_rates = [], []
    for r in range(repeats):  # interleaved timed repeats
        dense_rates.append(_events_per_sec(dense, steps))
        cohort_rates.append(_events_per_sec(coh, steps))
        print(f"  repeat {r}: dense {dense_rates[-1]:,.0f} ev/s, "
              f"cohort {cohort_rates[-1]:,.0f} ev/s", flush=True)
    d_med = float(np.median(dense_rates))
    c_med = float(np.median(cohort_rates))
    ratio = d_med / max(c_med, 1e-9)
    mm = coh.scheduler.memory_model()
    m_coh = coh.scheduler.extra_metrics()
    rec = {
        "name": f"population_n{pop_nodes}_c{cohort}",
        "dense_nodes": dense_nodes,
        "pop_nodes": pop_nodes,
        "cohort_capacity": cohort,
        "hidden": hidden,
        "n_params": int(coh.n_params),
        "steps": steps,
        "dense_events_per_s": dense_rates,
        "cohort_events_per_s": cohort_rates,
        "dense_events_per_s_median": d_med,
        "cohort_events_per_s_median": c_med,
        "dense_over_cohort_ratio": ratio,
        "events_total": m_coh["events_total"],
        "cohort_occupancy_mean": m_coh["cohort_occupancy_mean"],
        "cohort_overflow_total": m_coh["cohort_overflow_total"],
        "memory_model": mm,
        "memory_after": memory_snapshot(),
    }
    recs.append(rec)
    print(f"  median dense {d_med:,.0f} ev/s vs cohort {c_med:,.0f} ev/s "
          f"-> dense/cohort ratio {ratio:.2f} (gate <= 2.0)", flush=True)
    print(f"  hot set {mm['hot']['total']/1e6:.2f} MB vs cold population "
          f"{mm['cold']['total']/1e6:.1f} MB", flush=True)
    gate_ok = ratio <= 2.0
    rec["throughput_gate_ok"] = bool(gate_ok)
    return recs, gate_ok


def check_memory_independence(cohort: int, hidden: int, n_small: int,
                              n_large: int, chunk: int):
    """Hot-set bytes at fixed C must not depend on N — asserted on the
    analytic model of two engine instances and recorded."""
    small = _engine(n_small, cohort, hidden=hidden, chunk=chunk)
    large = _engine(n_large, cohort, hidden=hidden, chunk=chunk)
    hs, hl = (small.scheduler.memory_model()["hot"],
              large.scheduler.memory_model()["hot"])
    assert hs == hl, (
        f"hot-set bytes depend on N at fixed C={cohort}: {hs} vs {hl}"
    )
    print(f"  hot set at C={cohort}: {hl['total']/1e6:.2f} MB for both "
          f"N={n_small} and N={n_large} (N-independent)", flush=True)
    return {
        "name": f"memory_independence_c{cohort}",
        "n_small": n_small,
        "n_large": n_large,
        "hot_bytes": hl["total"],
        "cold_bytes_small": small.scheduler.memory_model()["cold"]["total"],
        "cold_bytes_large": large.scheduler.memory_model()["cold"]["total"],
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--pop-nodes", type=int, default=100_000)
    ap.add_argument("--dense-nodes", type=int, default=1024)
    ap.add_argument("--cohort", type=int, default=8192)
    ap.add_argument("--hidden", type=int, default=16,
                    help="MLP hidden width (P = feat*H + H + H*classes + "
                    "classes parameters per node)")
    ap.add_argument("--steps", type=int, default=32,
                    help="timed event steps per repeat")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--batch", type=int, default=8,
                    help="per-event local SGD batch size")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: small cohort/steps, single repeat, "
                    "assert the hot-set bound but skip the (noisy-in-CI) "
                    "throughput gate")
    ap.add_argument("--hot-bound-mb", type=float, default=64.0,
                    help="smoke-mode ceiling on analytic hot-set MB")
    args = ap.parse_args()
    if args.smoke:
        args.cohort = min(args.cohort, 256)
        args.steps = min(args.steps, 8)
        args.repeats = 1
        args.dense_nodes = min(args.dense_nodes, 256)
    recs = [{"name": "_memory_before", **memory_snapshot()}]
    recs.append(check_memory_independence(
        args.cohort, args.hidden, max(args.pop_nodes // 10, args.cohort),
        args.pop_nodes, args.chunk))
    run_recs, gate_ok = run_population(
        args.dense_nodes, args.pop_nodes, args.cohort, args.hidden,
        args.steps, args.repeats, args.chunk, args.batch)
    recs += run_recs
    path = save_results("bench_population", recs)
    print(f"[population] results -> {path}", flush=True)
    if args.smoke:
        hot = run_recs[0]["memory_model"]["hot"]["total"]
        assert hot <= args.hot_bound_mb * 1e6, (
            f"hot set {hot/1e6:.1f} MB exceeds the {args.hot_bound_mb} MB "
            "smoke bound")
        print(f"[population] smoke OK: hot set {hot/1e6:.2f} MB "
              f"<= {args.hot_bound_mb} MB", flush=True)
    elif not gate_ok:
        raise SystemExit("[population] FAIL: dense/cohort per-event "
                         "throughput ratio exceeds 2.0")


if __name__ == "__main__":
    main()
