"""Population-scale async engine benchmark — the cohort gather/scatter
gate (ISSUE 6 acceptance):

1. An N=100k asynchronous run completes at ``cohort_capacity``-bounded
   memory (hot working set O(C·(d+1)·P), independent of N — asserted
   against the scheduler's analytic ``memory_model`` at two population
   sizes and recorded empirically via live device-buffer bytes).
2. Per-active-node event throughput of the cohort path at N=100k is
   within 2x of the dense-oracle cohort rate at N=1024 (recorded median
   over interleaved repeats).

The workload is a small per-node MLP (the paper's model family at toy
scale) trained by per-event local SGD — a fired event pays realistic
gradient FLOPs, so the gate compares end-to-end per-event cost, not just
bookkeeping.  Both runs use homogeneous event times and
``async_slice_s=0`` so every step fires a full cohort: the dense N=1024
baseline fires 1024 events per step over an O(N·(d+1)·P) working set;
the cohort N=100k run fires C events per step over O(C·(d+1)·P) plus
O(N) selection/scatter.

The million-node stage (ISSUE 10 acceptance) adds:

3. N=1,000,000 cohort throughput >= 0.5x the N=100k rate at the same C
   (median over interleaved repeats) — per-step cost stays sublinear in
   N because selection runs through the carried segment-min hierarchy
   and the cold (N, P) population is int8-quantized.
4. Cold-state bytes at N=1M with ``cold_dtype='int8'`` <= 0.3x the fp32
   cold bytes of ``memory_model()``, and the live device-buffer snapshot
   confirms the analytic model within 1.5x.
5. The hierarchical selection is checked bitwise against the flat top_k
   oracle on a small-N run, and the vectorized random-regular builder is
   timed at N=1M (its wall-clock lands in the results record).

Records land in ``results/bench_population.json`` (uploaded by CI); the
shared ``save_results`` appends live-device-bytes + host-RSS capture.
"""
from __future__ import annotations

import argparse
import gc
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import memory_snapshot, save_results
from repro.core import DLConfig, RoundEngine
from repro.core.topology import random_regular_neighbors
from repro.data import NodeBatcher
from repro.optim import make_optimizer

SHAPE = (4, 4, 1)
N_CLASSES = 2


def _make_init(hidden: int):
    feat = int(np.prod(SHAPE))

    def init(k):
        k1, k2 = jax.random.split(k)
        return {
            "w1": jax.random.normal(k1, (feat, hidden)) / np.sqrt(feat),
            "b1": jnp.zeros((hidden,)),
            "w2": jax.random.normal(k2, (hidden, N_CLASSES)) / np.sqrt(hidden),
            "b2": jnp.zeros((N_CLASSES,)),
        }

    return init


def _apply(p, x):
    h = jnp.tanh(x.reshape(x.shape[0], -1) @ p["w1"] + p["b1"])
    return h @ p["w2"] + p["b2"]


def _loss(p, x, y):
    logp = jax.nn.log_softmax(_apply(p, x))
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def _acc(p, x, y):
    return (_apply(p, x).argmax(-1) == y).mean()


def _engine(n_nodes: int, cohort: int, *, hidden: int, chunk: int,
            batch: int = 4, degree: int = 4, seed: int = 0,
            selection: str = "auto", cold: str = "fp32",
            spread: float = 0.0, slice_s: float = 0.0) -> RoundEngine:
    """Async MLP-per-node engine: each fired event runs one local SGD
    step of a (feat -> hidden -> classes) MLP and a neighborhood gossip,
    with ms-scale event times and no network model.  ``spread`` turns on
    continuous per-node compute heterogeneity (U(1, 1+spread) x base) and
    ``slice_s`` the cohort window — together they put selection in the
    spread-clock regime where the segment hierarchy prunes."""
    rng = np.random.default_rng(seed)
    n_train = max(n_nodes, 256)
    x = rng.normal(size=(n_train, *SHAPE)).astype(np.float32)
    y = rng.integers(0, N_CLASSES, size=(n_train,)).astype(np.int32)
    parts = np.array_split(np.arange(n_train), n_nodes)
    dl = DLConfig(
        n_nodes=n_nodes, topology="regular", degree=degree, sharing="full",
        semantics="async", async_gossip="neighborhood",
        async_slice_s=slice_s, chunk_rounds=chunk, eval_every=10_000,
        batch_size=batch, compute_time_s=1e-3, cohort_capacity=cohort,
        seed=seed, batch_keying="node", selection=selection,
        cold_dtype=cold, compute_spread=spread,
    )
    batcher = NodeBatcher(x, y, parts, dl.batch_size, seed=seed)
    return RoundEngine(dl, _make_init(hidden), _loss, _acc,
                       make_optimizer("sgd", 0.05), batcher)


def _events_per_sec(eng: RoundEngine, steps: int) -> float:
    """Fired events per wall second over ``steps`` scanned event steps
    (post-warmup; the caller interleaves repeats)."""
    sched = eng.scheduler
    start = getattr(eng, "_bench_round", 0)
    before = sched._fired_total
    t0 = time.perf_counter()
    done = 0
    while done < steps:
        r = min(eng.chunk, steps - done)
        sched.run_span(start + done, r)
        done += r
    jax.block_until_ready(eng.params)
    dt = time.perf_counter() - t0
    eng._bench_round = start + done
    return (sched._fired_total - before) / max(dt, 1e-9)


def run_population(dense_nodes: int, pop_nodes: int, cohort: int,
                   hidden: int, steps: int, repeats: int, chunk: int,
                   batch: int):
    recs = []
    print(f"[population] dense N={dense_nodes} oracle vs "
          f"cohort N={pop_nodes} C={cohort} (hidden={hidden}, B={batch}, "
          f"{steps} steps, {repeats} repeats)", flush=True)
    t0 = time.time()
    dense = _engine(dense_nodes, 0, hidden=hidden, chunk=chunk, batch=batch)
    coh = _engine(pop_nodes, cohort, hidden=hidden, chunk=chunk, batch=batch)
    print(f"  engines built in {time.time() - t0:.1f}s", flush=True)
    # warmup: compile both full-length chunk programs (a shorter span
    # would compile a different scan length and leak the timed repeats'
    # first-call compile into the measurement)
    dense.scheduler.run_span(0, chunk)
    coh.scheduler.run_span(0, chunk)
    dense._bench_round = coh._bench_round = chunk
    dense_rates, cohort_rates = [], []
    for r in range(repeats):  # interleaved timed repeats
        dense_rates.append(_events_per_sec(dense, steps))
        cohort_rates.append(_events_per_sec(coh, steps))
        print(f"  repeat {r}: dense {dense_rates[-1]:,.0f} ev/s, "
              f"cohort {cohort_rates[-1]:,.0f} ev/s", flush=True)
    d_med = float(np.median(dense_rates))
    c_med = float(np.median(cohort_rates))
    ratio = d_med / max(c_med, 1e-9)
    mm = coh.scheduler.memory_model()
    m_coh = coh.scheduler.extra_metrics()
    rec = {
        "name": f"population_n{pop_nodes}_c{cohort}",
        "dense_nodes": dense_nodes,
        "pop_nodes": pop_nodes,
        "cohort_capacity": cohort,
        "hidden": hidden,
        "n_params": int(coh.n_params),
        "steps": steps,
        "dense_events_per_s": dense_rates,
        "cohort_events_per_s": cohort_rates,
        "dense_events_per_s_median": d_med,
        "cohort_events_per_s_median": c_med,
        "dense_over_cohort_ratio": ratio,
        "events_total": m_coh["events_total"],
        "cohort_occupancy_mean": m_coh["cohort_occupancy_mean"],
        "cohort_overflow_total": m_coh["cohort_overflow_total"],
        "cohort_overflow_ratio": m_coh["cohort_overflow_ratio"],
        "cohort_selection": m_coh["cohort_selection"],
        "memory_model": mm,
        "memory_after": memory_snapshot(),
    }
    recs.append(rec)
    print(f"  median dense {d_med:,.0f} ev/s vs cohort {c_med:,.0f} ev/s "
          f"-> dense/cohort ratio {ratio:.2f} (gate <= 2.0)", flush=True)
    print(f"  hot set {mm['hot']['total']/1e6:.2f} MB vs cold population "
          f"{mm['cold']['total']/1e6:.1f} MB", flush=True)
    gate_ok = ratio <= 2.0
    rec["throughput_gate_ok"] = bool(gate_ok)
    return recs, gate_ok


def check_memory_independence(cohort: int, hidden: int, n_small: int,
                              n_large: int, chunk: int):
    """Hot-set bytes at fixed C must not depend on N — asserted on the
    analytic model of two engine instances and recorded."""
    small = _engine(n_small, cohort, hidden=hidden, chunk=chunk)
    large = _engine(n_large, cohort, hidden=hidden, chunk=chunk)
    hs, hl = (small.scheduler.memory_model()["hot"],
              large.scheduler.memory_model()["hot"])
    assert hs == hl, (
        f"hot-set bytes depend on N at fixed C={cohort}: {hs} vs {hl}"
    )
    print(f"  hot set at C={cohort}: {hl['total']/1e6:.2f} MB for both "
          f"N={n_small} and N={n_large} (N-independent)", flush=True)
    return {
        "name": f"memory_independence_c{cohort}",
        "n_small": n_small,
        "n_large": n_large,
        "hot_bytes": hl["total"],
        "cold_bytes_small": small.scheduler.memory_model()["cold"]["total"],
        "cold_bytes_large": large.scheduler.memory_model()["cold"]["total"],
    }


# continuous heterogeneity used by the selection-oracle check and the
# million-node stage: per-node compute ~ 1e-3 * U(1, 1 + SPREAD) seconds
SPREAD = 15.0


def _slice_for(n: int, cohort: int, *, fill: float = 0.8) -> float:
    """Cohort window sized so the steady-state occupancy is ~fill*C:
    with per-node rate 1/ct and ct ~ base*U(1, 1+SPREAD), the population
    event rate is N * ln(1+SPREAD) / (base*SPREAD) events/s."""
    rate = n * np.log1p(SPREAD) / (1e-3 * SPREAD)
    return fill * cohort / rate


def check_selection_oracle(chunk: int, hidden: int, *, n: int = 4096,
                           cohort: int = 256, steps: int = 24,
                           batch: int = 4):
    """Hierarchical segment-min selection must pick bitwise the same
    cohorts as the flat top_k oracle: run both paths under a continuous
    heterogeneous clock and compare the full trajectory (params + event
    counters) exactly.  Also asserts the hierarchy actually engaged —
    fallbacks on every step would make the check vacuous."""
    sl = _slice_for(n, cohort)
    flat = _engine(n, cohort, hidden=hidden, chunk=chunk, batch=batch,
                   selection="flat", spread=SPREAD, slice_s=sl)
    hier = _engine(n, cohort, hidden=hidden, chunk=chunk, batch=batch,
                   selection="hier", spread=SPREAD, slice_s=sl)
    for e in (flat, hier):
        done = 0
        while done < steps:
            r = min(e.chunk, steps - done)
            e.scheduler.run_span(done, r)
            done += r
    for a, b in zip(jax.tree_util.tree_leaves(flat.params),
                    jax.tree_util.tree_leaves(hier.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(flat.scheduler._events),
                                  np.asarray(hier.scheduler._events))
    fb = hier.scheduler.extra_metrics()["selection_fallback_total"]
    assert fb < steps, (
        f"hier selection fell back to flat on all {steps} steps — the "
        "oracle check never exercised the segment hierarchy")
    print(f"  selection oracle OK: hier == flat bitwise over {steps} steps "
          f"at N={n} C={cohort} (fallbacks: {fb}/{steps})", flush=True)
    return {
        "name": f"selection_oracle_n{n}_c{cohort}",
        "steps": steps,
        "bitwise_equal": True,
        "selection_fallback_total": fb,
    }


def run_million(base_nodes: int, million_nodes: int, cohort: int,
                hidden: int, steps: int, repeats: int, chunk: int,
                batch: int, cold: str, smoke: bool):
    """The N=1M stage: hierarchical selection + compressed cold rows.

    Full mode interleaves the million-node cohort engine against the
    N=``base_nodes`` cohort engine at the same C and gates the median
    per-event rate at >= 0.5x (which also pins the 10x-N per-step cost
    growth at <= 2x — far below linear).  Smoke mode runs the million
    engine alone (small C, few steps) and checks the memory claims only.
    """
    recs = []
    print(f"[population] million-node stage: N={million_nodes} C={cohort} "
          f"cold_dtype={cold} (vs N={base_nodes} baseline"
          f"{', smoke' if smoke else ''})", flush=True)
    # vectorized random-regular builder at N=1M (the ROADMAP follow-up
    # this stage retires): build once, record wall-clock
    t0 = time.perf_counter()
    nbr = random_regular_neighbors(million_nodes, 6, seed=0)
    rr_s = time.perf_counter() - t0
    assert nbr.shape == (million_nodes, 6) and nbr.dtype == np.int32
    print(f"  random_regular_neighbors(N={million_nodes}, d=6): "
          f"{rr_s:.1f}s", flush=True)
    del nbr
    gc.collect()
    base = None
    if not smoke:
        base = _engine(base_nodes, cohort, hidden=hidden, chunk=chunk,
                       batch=batch, spread=SPREAD,
                       slice_s=_slice_for(base_nodes, cohort))
    t0 = time.time()
    big = _engine(million_nodes, cohort, hidden=hidden, chunk=chunk,
                  batch=batch, selection="hier", cold=cold, spread=SPREAD,
                  slice_s=_slice_for(million_nodes, cohort))
    build_s = time.time() - t0
    print(f"  N={million_nodes} engine built in {build_s:.1f}s "
          f"(selection=hier, cold_dtype={cold})", flush=True)
    def _warm(e, n_nodes):
        # warm to the event clock's steady state: occupancy ramps from the
        # initial-transient fill to ~0.8*C over ~N/C steps (every node has
        # to fire once before the spread clock is stationary); timing the
        # ramp would understate the steady rate.  Chunk-multiple so the
        # jitted span length stays fixed.
        warm = chunk if smoke else max(chunk, (3 * n_nodes) // (2 * cohort))
        warm = -(-warm // chunk) * chunk
        done = 0
        while done < warm:
            e.scheduler.run_span(done, chunk)
            done += chunk
        e._bench_round = done
        return done

    if base is not None:
        _warm(base, base_nodes)
    warm_steps = _warm(big, million_nodes)
    print(f"  warmed N={million_nodes} for {warm_steps} steps "
          f"(occupancy steady state)", flush=True)
    base_rates, big_rates = [], []
    for r in range(repeats):
        if base is not None:
            base_rates.append(_events_per_sec(base, steps))
        big_rates.append(_events_per_sec(big, steps))
        print(f"  repeat {r}: "
              + (f"N={base_nodes} {base_rates[-1]:,.0f} ev/s, "
                 if base_rates else "")
              + f"N={million_nodes} {big_rates[-1]:,.0f} ev/s", flush=True)
    big_med = float(np.median(big_rates))
    base_med = float(np.median(base_rates)) if base_rates else 0.0
    mm = big.scheduler.memory_model()
    m_big = big.scheduler.extra_metrics()
    cold_ratio = mm["cold"]["total"] / max(mm["cold"]["total_fp32"], 1)
    # live-vs-analytic check on the million engine alone: drop the
    # baseline first so its buffers don't pollute the live-bytes sum
    del base
    gc.collect()
    snap = memory_snapshot()
    dataset_bytes = int(
        big._dev_x.nbytes + big._dev_y.nbytes
        + big._dev_lens.nbytes + big._dev_parts_pad.nbytes
    )
    analytic = mm["hot"]["total"] + mm["cold"]["total"] + dataset_bytes
    live_ratio = snap["device_live_bytes"] / max(analytic, 1)
    rec = {
        "name": f"million_n{million_nodes}_c{cohort}_{cold}",
        "base_nodes": base_nodes,
        "million_nodes": million_nodes,
        "cohort_capacity": cohort,
        "cold_dtype": cold,
        "n_params": int(big.n_params),
        "steps": steps,
        "build_s": build_s,
        "random_regular_1m_build_s": rr_s,
        "base_events_per_s": base_rates,
        "million_events_per_s": big_rates,
        "base_events_per_s_median": base_med,
        "million_events_per_s_median": big_med,
        "million_over_base_ratio": big_med / base_med if base_med else None,
        "events_total": m_big["events_total"],
        "cohort_occupancy_mean": m_big["cohort_occupancy_mean"],
        "cohort_overflow_total": m_big["cohort_overflow_total"],
        "cohort_overflow_ratio": m_big["cohort_overflow_ratio"],
        "selection_fallback_total": m_big["selection_fallback_total"],
        "cold_bytes": mm["cold"]["total"],
        "cold_bytes_fp32": mm["cold"]["total_fp32"],
        "cold_over_fp32_ratio": cold_ratio,
        "dataset_bytes": dataset_bytes,
        "analytic_total_bytes": analytic,
        "live_over_analytic_ratio": live_ratio,
        "memory_model": mm,
        "memory_after": snap,
    }
    recs.append(rec)
    print(f"  cold {mm['cold']['total']/1e6:.0f} MB vs fp32 "
          f"{mm['cold']['total_fp32']/1e6:.0f} MB "
          f"(ratio {cold_ratio:.3f}); live/analytic {live_ratio:.2f}",
          flush=True)
    gates_ok = True
    if cold == "int8" and cold_ratio > 0.3:
        print(f"[population] FAIL: int8 cold bytes ratio {cold_ratio:.3f} "
              "> 0.3", flush=True)
        gates_ok = False
    total_steps = warm_steps + repeats * steps
    if m_big["selection_fallback_total"] >= total_steps:
        print(f"[population] FAIL: hier selection fell back to the flat "
              f"oracle on all {total_steps} steps — the segment hierarchy "
              "never engaged", flush=True)
        gates_ok = False
    if not smoke:
        ratio = big_med / max(base_med, 1e-9)
        print(f"  median N={base_nodes} {base_med:,.0f} ev/s vs "
              f"N={million_nodes} {big_med:,.0f} ev/s -> ratio "
              f"{ratio:.2f} (gate >= 0.5, 10x N)", flush=True)
        if ratio < 0.5:
            print("[population] FAIL: million-node throughput below 0.5x "
                  "the 100k rate", flush=True)
            gates_ok = False
        if not (1 / 1.5 <= live_ratio <= 1.5):
            print(f"[population] FAIL: live/analytic memory ratio "
                  f"{live_ratio:.2f} outside [0.67, 1.5]", flush=True)
            gates_ok = False
        rec["throughput_gate_ok"] = bool(big_med >= 0.5 * base_med)
    return recs, gates_ok


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--pop-nodes", type=int, default=100_000)
    ap.add_argument("--dense-nodes", type=int, default=1024)
    ap.add_argument("--cohort", type=int, default=8192)
    ap.add_argument("--hidden", type=int, default=16,
                    help="MLP hidden width (P = feat*H + H + H*classes + "
                    "classes parameters per node)")
    ap.add_argument("--steps", type=int, default=32,
                    help="timed event steps per repeat")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--batch", type=int, default=8,
                    help="per-event local SGD batch size")
    ap.add_argument("--million-nodes", type=int, default=1_000_000,
                    help="population of the million-node stage (0 = skip)")
    ap.add_argument("--million-cohort", type=int, default=0,
                    help="cohort capacity of the million-node stage "
                    "(0 = same as --cohort)")
    ap.add_argument("--cold-dtype", default="int8",
                    choices=["fp32", "bf16", "int8"],
                    help="cold population storage of the million-node stage")
    ap.add_argument("--million-only", action="store_true",
                    help="run only the million-node stage (+ selection "
                    "oracle check) — the CI N=1M smoke entry point")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: small cohort/steps, single repeat, "
                    "assert the hot-set/cold-bytes bounds and the "
                    "selection oracle but skip the (noisy-in-CI) "
                    "throughput gates")
    ap.add_argument("--hot-bound-mb", type=float, default=64.0,
                    help="smoke-mode ceiling on analytic hot-set MB")
    args = ap.parse_args()
    if args.smoke:
        args.cohort = min(args.cohort, 256)
        args.steps = min(args.steps, 8)
        args.repeats = 1
        args.dense_nodes = min(args.dense_nodes, 256)
    million_cohort = args.million_cohort or args.cohort
    recs = [{"name": "_memory_before", **memory_snapshot()}]
    recs.append(check_selection_oracle(args.chunk, args.hidden))
    gate_ok = True
    run_recs = []
    if not args.million_only:
        recs.append(check_memory_independence(
            args.cohort, args.hidden, max(args.pop_nodes // 10, args.cohort),
            args.pop_nodes, args.chunk))
        run_recs, gate_ok = run_population(
            args.dense_nodes, args.pop_nodes, args.cohort, args.hidden,
            args.steps, args.repeats, args.chunk, args.batch)
        recs += run_recs
    million_ok = True
    if args.million_nodes > 0:
        m_recs, million_ok = run_million(
            args.pop_nodes, args.million_nodes, million_cohort, args.hidden,
            args.steps, args.repeats, args.chunk, args.batch,
            args.cold_dtype, args.smoke)
        recs += m_recs
        if args.smoke:
            hot = m_recs[0]["memory_model"]["hot"]["total"]
            assert hot <= args.hot_bound_mb * 1e6, (
                f"million-stage hot set {hot/1e6:.1f} MB exceeds the "
                f"{args.hot_bound_mb} MB smoke bound")
            print(f"[population] million smoke OK: hot set {hot/1e6:.2f} MB "
                  f"<= {args.hot_bound_mb} MB", flush=True)
    path = save_results("bench_population", recs)
    print(f"[population] results -> {path}", flush=True)
    if not million_ok:
        raise SystemExit("[population] FAIL: million-node stage gate "
                         "(see log above)")
    if args.smoke:
        if run_recs:
            hot = run_recs[0]["memory_model"]["hot"]["total"]
            assert hot <= args.hot_bound_mb * 1e6, (
                f"hot set {hot/1e6:.1f} MB exceeds the "
                f"{args.hot_bound_mb} MB smoke bound")
            print(f"[population] smoke OK: hot set {hot/1e6:.2f} MB "
                  f"<= {args.hot_bound_mb} MB", flush=True)
    elif not gate_ok:
        raise SystemExit("[population] FAIL: dense/cohort per-event "
                         "throughput ratio exceeds 2.0")


if __name__ == "__main__":
    main()
