"""Paper Fig. 6 + §3.5: scalability — 256-node vs 1024-node 5-regular
(4x fewer samples per node at 1024), and degree 5 vs degree 9 at the
larger scale.

Paper claims validated: 5-regular@1024 ~ 5-regular@256 despite 4x less
data per node; degree 9 beats degree 5 (paper: +5.8 points)."""
from __future__ import annotations

import argparse

from repro.core import DLConfig

from benchmarks.common import dl_experiment, save_results


def run(base_nodes: int = 256, rounds: int = 60, model: str = "mlp", seeds: int = 1,
        log: bool = True, n_train: int = 16384):
    recs = []
    for name, nodes, degree in [
        (f"{base_nodes}n-5reg", base_nodes, 5),
        (f"{base_nodes * 4}n-5reg", base_nodes * 4, 5),
        (f"{base_nodes * 4}n-9reg", base_nodes * 4, 9),
    ]:
        dl = DLConfig(n_nodes=nodes, topology="regular", degree=degree, rounds=rounds,
                      eval_every=max(rounds // 6, 1), local_steps=2, batch_size=8)
        recs.append(
            dl_experiment(name, dl, model=model, width=8, n_train=n_train,
                          sigma=4.0, seeds=seeds, log=log)
        )
    save_results("bench_scalability", recs)
    return recs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--base-nodes", type=int, default=256)
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--seeds", type=int, default=1)
    args = ap.parse_args()
    recs = run(args.base_nodes, args.rounds, seeds=args.seeds)
    print("\nname,acc,bytes_per_node_MB")
    for r in recs:
        print(f"{r['name']},{r['acc_mean']:.4f},{r['bytes_per_node']/1e6:.1f}")


if __name__ == "__main__":
    main()
