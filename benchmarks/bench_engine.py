"""Engine throughput: legacy per-round dispatch vs scanned chunks.

Measures rounds/sec of the RoundEngine at chunk sizes 0 (legacy host-driven
per-round dispatch with host-stacked batches), 1, 8, 32 for N in {64, 256}.

The workload is a distributed-consensus round — each node pulls its local
batch toward its mean with a quadratic loss, then gossips — deliberately
the cheapest possible per-round device program, so the measurement isolates
the *execution machinery* (per-round dispatch, host batch staging,
host<->device metric syncs) rather than model FLOPs, which are identical
across chunk sizes.  Training benchmarks (bench_scalability etc.) cover the
model-bound regime.

    PYTHONPATH=src python benchmarks/bench_engine.py --rounds 64

Results go through benchmarks/common.save_results so the perf trajectory
is recorded (results/bench_engine.json).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import DLConfig, RoundEngine
from repro.data import NodeBatcher, make_dataset, sharding_partition
from repro.optim import make_optimizer

from benchmarks.common import save_results

SHAPE = (2, 2, 1)  # 4-dim inputs -> 4-param consensus state per node


def _init(key):
    return {"w": jax.random.normal(key, (SHAPE[0] * SHAPE[1] * SHAPE[2],))}


def _loss(p, x, y):
    return jnp.mean((p["w"] - x.reshape(x.shape[0], -1).mean(0)) ** 2)


def _acc(p, x, y):
    return -_loss(p, x, y)  # consensus error, negated so bigger = better


def _engine(n_nodes: int, chunk: int) -> RoundEngine:
    ds = make_dataset("cifar10", n_train=2048, n_test=64, shape=SHAPE, sigma=2.0)
    parts = sharding_partition(ds.train_y, n_nodes, 2, seed=0)
    batcher = NodeBatcher(ds.train_x, ds.train_y, parts, batch_size=4, seed=0)
    dl = DLConfig(n_nodes=n_nodes, topology="regular", degree=5,
                  eval_every=10**9, local_steps=1, batch_size=4,
                  chunk_rounds=chunk)
    return RoundEngine(dl, _init, _loss, _acc, make_optimizer("sgd", 0.05), batcher)


def run(rounds: int = 64, nodes=(64, 256), chunks=(0, 1, 8, 32), repeats: int = 5,
        log: bool = True):
    recs = []
    for n in nodes:
        rps = {}
        for chunk in chunks:
            eng = _engine(n, chunk)
            # warm up with the same round count so every scan length the
            # timed run needs (full chunks + remainder) is already compiled
            eng.run(rounds=rounds, log=False)
            best = 0.0
            for _ in range(repeats):
                t0 = time.time()
                eng.run(rounds=rounds, log=False)
                best = max(best, rounds / (time.time() - t0))
            rps[chunk] = best
            name = "legacy" if chunk == 0 else f"chunk{chunk}"
            recs.append({
                "name": f"N{n}-{name}", "n_nodes": n, "chunk": chunk,
                "rounds": rounds, "rounds_per_s": best,
            })
            if log:
                print(f"  N={n:4d} {name:8s} {best:8.1f} rounds/s", flush=True)
        if log and 1 in rps and 32 in rps:
            line = f"  N={n:4d} speedup chunk32/chunk1: {rps[32] / rps[1]:.2f}x"
            if 0 in rps:
                line += f", chunk32/legacy: {rps[32] / rps[0]:.2f}x"
            print(line, flush=True)
    save_results("bench_engine", recs)
    return recs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=64)
    ap.add_argument("--nodes", type=int, nargs="+", default=[64, 256])
    ap.add_argument("--repeats", type=int, default=5)
    args = ap.parse_args()
    recs = run(args.rounds, tuple(args.nodes), repeats=args.repeats)
    print("\nname,rounds_per_s")
    for r in recs:
        print(f"{r['name']},{r['rounds_per_s']:.1f}")


if __name__ == "__main__":
    main()
