"""Engine throughput: legacy per-round dispatch vs scanned chunks, and
sparse neighbor-indexed mixing vs dense W @ X at the paper's 1000+ node
emulation scale.

Part 1 measures rounds/sec of the RoundEngine at chunk sizes 0 (legacy
host-driven per-round dispatch with host-stacked batches), 1, 8, 32 for N
in {64, 256} — the perf regression gate is chunk=32 ≥ 3x chunk=1 at N=256.

Part 2 measures sparse vs dense mixing at N=1024, d=6, chunk=32 on static
d-regular and dynamic (per-round random d-regular) topologies, recording
rounds/s and the peak per-chunk topology staging bytes: the sparse path
stages (R, N, D) neighbor tables (O(N·d)) and keeps full-length chunks,
while the dense path stages (R, N, N) W stacks that hit the 64 MB cap and
silently shrink the chunk exactly where scale matters.  Gate: sparse ≥ 3x
dense rounds/s at N=1024.

Part 3 measures the node-sharded engine (shard_devices=8, both the
'gather' and the collective_permute 'ppermute' gossip lowerings) against
the single-device engine at N=1024, d=6 on 8 CPU-emulated devices — the
honest emulation cost of multi-device execution on one box (emulated
collectives are host rendezvous; the wire win is a TPU story).  Runs in a
subprocess with XLA_FLAGS set when the current process has fewer devices.

Part 4 measures payload-form compressed sharing (DLConfig.payload='on':
(N, k) idx/val payloads aggregated in one O(N·d·k) scatter pass) against
the dense-mask oracle ('off': scattered (N, P) masks + two apply_W
passes) at N=1024, d=6, budget=0.01, chunk=32 — the paper's sparsified
1000+-node scenario where the wire format, not the math, decides
throughput.  Gates: payload ≥ 3x dense-mask rounds/s (median), and the
sharing stage's per-round staged message bytes reduced ≥ 10x.

All timed sections record min/median/mean rounds/s over the repeats; the
headline ``rounds_per_s`` (and any CI threshold) is the *median* — this
box's spread under load makes best-of-N misleading.

The workload is a distributed-consensus round — each node pulls its local
batch toward its mean with a quadratic loss, then gossips — deliberately
the cheapest possible per-round device program, so the measurement isolates
the *execution machinery* (per-round dispatch, host batch staging, mixing
FLOPs and topology staging, host<->device metric syncs) rather than model
FLOPs.  Training benchmarks (bench_scalability etc.) cover the model-bound
regime.

    PYTHONPATH=src python benchmarks/bench_engine.py --rounds 64

Results go through benchmarks/common.save_results so the perf trajectory
is recorded (results/bench_engine.json).
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import subprocess
import sys
import time

import jax
import jax.numpy as jnp

from repro.core import DLConfig, RoundEngine
from repro.data import NodeBatcher, make_dataset, sharding_partition
from repro.optim import make_optimizer

from benchmarks.common import save_results

SHAPE = (2, 2, 1)  # 4-dim inputs; batch staging stays negligible
P_DISPATCH = 4     # part 1: 4-param state isolates the dispatch machinery
P_MIXING = 256     # part 2: 256-param state so mixing FLOPs are the measured axis
P_PAYLOAD = 1024   # part 4: 1024-param state so the sharing stage dominates
#                    (budget 0.01 -> k=10 payload coords per node)


def _rps_stats(samples):
    """min/median/mean rounds-per-second over the timed repeats.  The box
    is noisy (3.4-16x spread observed under load), so recorded headline
    numbers and CI gates use the *median*, not best-of-N."""
    return {
        "rounds_per_s": statistics.median(samples),
        "rounds_per_s_min": min(samples),
        "rounds_per_s_mean": sum(samples) / len(samples),
    }


def _loss(p, x, y):
    # consensus: pull every 4-wide row of the state toward the local batch
    # mean — the state dim P is free while the dataset stays 4-dim
    t = x.reshape(x.shape[0], -1).mean(0)
    return jnp.mean((p["w"].reshape(-1, t.shape[0]) - t) ** 2)


def _acc(p, x, y):
    return -_loss(p, x, y)  # consensus error, negated so bigger = better


def _engine(n_nodes: int, chunk: int, topology: str = "regular", degree: int = 5,
            mixing: str = "auto", p_dim: int = P_DISPATCH, **dl_kw) -> RoundEngine:
    ds = make_dataset("cifar10", n_train=2048, n_test=64, shape=SHAPE, sigma=2.0)
    parts = sharding_partition(ds.train_y, n_nodes, 2, seed=0)
    batcher = NodeBatcher(ds.train_x, ds.train_y, parts, batch_size=4, seed=0)
    dl_kw = {"local_steps": 1, "eval_every": 10**9, **dl_kw}
    dl = DLConfig(n_nodes=n_nodes, topology=topology, degree=degree,
                  batch_size=4,
                  chunk_rounds=chunk, mixing=mixing, **dl_kw)
    init = lambda key: {"w": jax.random.normal(key, (p_dim,))}
    return RoundEngine(dl, init, _loss, _acc, make_optimizer("sgd", 0.05), batcher)


def run(rounds: int = 64, nodes=(64, 256), chunks=(0, 1, 8, 32), repeats: int = 5,
        log: bool = True, save: bool = True):
    recs = []
    if rounds <= 0:  # CI runs the two sections as separate smoke steps
        return recs
    for n in nodes:
        rps = {}
        for chunk in chunks:
            eng = _engine(n, chunk)
            # warm up with the same round count so every scan length the
            # timed run needs (full chunks + remainder) is already compiled
            eng.run(rounds=rounds, log=False)
            samples = []
            for _ in range(repeats):
                t0 = time.time()
                eng.run(rounds=rounds, log=False)
                samples.append(rounds / (time.time() - t0))
            stats = _rps_stats(samples)
            rps[chunk] = stats["rounds_per_s"]
            name = "legacy" if chunk == 0 else f"chunk{chunk}"
            recs.append({
                "name": f"N{n}-{name}", "n_nodes": n, "chunk": chunk,
                "rounds": rounds, **stats,
            })
            if log:
                print(f"  N={n:4d} {name:8s} {stats['rounds_per_s']:8.1f} rounds/s "
                      f"(min {stats['rounds_per_s_min']:.1f})", flush=True)
        if log and 1 in rps and 32 in rps:
            line = f"  N={n:4d} speedup chunk32/chunk1: {rps[32] / rps[1]:.2f}x"
            if 0 in rps:
                line += f", chunk32/legacy: {rps[32] / rps[0]:.2f}x"
            print(line, flush=True)
    if save:
        save_results("bench_engine", recs)
    return recs


def run_sparse(rounds: int = 32, n: int = 1024, degree: int = 6, chunk: int = 32,
               repeats: int = 3, topologies=("dynamic",), log: bool = True):
    """Sparse-vs-dense mixing at emulation scale (N=1024, d=6, chunk=32).

    The gate case is the *dynamic* per-round d-regular topology — the
    paper's 1000+-node scenario — where the dense path structurally loses
    three ways: O(N²·P) mixing FLOPs, (R, N, N) host W-stack builds +
    transfers, and chunk shrinkage under the 64 MB W-stack cap (visible in
    ``chunk_effective``); sparse ≥ 3x dense holds across box load.  A
    static-graph comparison is e2e-noisy on a CPU box (XLA's serial gather
    vs a multithreaded matmul under throttling), so the static claim is
    covered by the isolated mixing-op micro (``_mix_op_micro``) appended
    to the records; pass topologies=("regular", "dynamic") for the e2e
    static case too.

    Uses a P=256 consensus state (P_MIXING; dataset stays 4-dim so batch
    staging is unchanged) so the mixing term is the measured axis rather
    than rounding error next to the fixed per-round dispatch cost.
    Records rounds/s, the effective chunk length, and peak per-chunk
    topology staging bytes."""
    recs = []
    for topo in topologies:
        engines = {}
        for mixing in ("dense", "sparse"):
            eng = _engine(n, chunk, topology=topo, degree=degree, mixing=mixing,
                          p_dim=P_MIXING)
            eng.run(rounds=rounds, log=False)  # warm-up compiles every scan length
            engines[mixing] = eng
        # interleave timed repeats so box-level CPU throttling hits both
        # paths equally and the ratio stays meaningful
        samples = {"dense": [], "sparse": []}
        for _ in range(repeats):
            for mixing, eng in engines.items():
                t0 = time.time()
                eng.run(rounds=rounds, log=False)
                samples[mixing].append(rounds / (time.time() - t0))
        rps = {}
        for mixing, eng in engines.items():
            stats = _rps_stats(samples[mixing])
            rps[mixing] = stats["rounds_per_s"]
            recs.append({
                "name": f"N{n}-d{degree}-{topo}-{mixing}", "n_nodes": n,
                "degree": degree, "topology": topo, "mixing": mixing,
                "chunk": chunk, "chunk_effective": eng.chunk, "rounds": rounds,
                **stats,
                "topo_stage_peak_bytes": eng.topo_stage_bytes_peak,
            })
            if log:
                print(f"  N={n} d={degree} {topo:8s} {mixing:6s} "
                      f"{rps[mixing]:8.1f} rounds/s  chunk_eff={eng.chunk}"
                      f"  topo_stage={eng.topo_stage_bytes_peak / 1e6:.2f}MB",
                      flush=True)
        if log:
            print(f"  N={n} d={degree} {topo:8s} speedup sparse/dense: "
                  f"{rps['sparse'] / rps['dense']:.2f}x", flush=True)
    recs += _mix_op_micro(n, degree, P_MIXING, log=log)
    return recs


def _mix_op_micro(n: int, degree: int, p: int, iters: int = 100, log: bool = True):
    """Isolated W @ X op: neighbor-indexed gather+contract vs dense matmul
    — the undiluted O(N·d·P) vs O(N²·P) mixing cost, without the round
    program's shared O(N·P) costs (local train, state packing)."""
    from repro.core.mixing import apply_W
    from repro.core.topology import Graph, SparseTopology

    g = Graph.regular_circulant(n, degree)
    st = SparseTopology.from_graph(g)
    ops = {
        "sparse": jax.jit(lambda x, t=jax.tree_util.tree_map(jnp.asarray, st):
                          apply_W(t, x)),
        "dense": jax.jit(lambda x, W=jnp.asarray(g.metropolis_hastings(),
                                                 jnp.float32): apply_W(W, x)),
    }
    X = jax.random.normal(jax.random.key(0), (n, p))
    recs = []
    us = {}
    for mixing, f in ops.items():
        f(X).block_until_ready()
        t0 = time.time()
        for _ in range(iters):
            out = f(X)
        out.block_until_ready()
        us[mixing] = (time.time() - t0) / iters * 1e6
        recs.append({"name": f"N{n}-d{degree}-P{p}-mixop-{mixing}", "n_nodes": n,
                     "degree": degree, "mixing": mixing, "op_us": us[mixing]})
        if log:
            print(f"  N={n} d={degree} P={p} mixop {mixing:6s} {us[mixing]:8.1f} us",
                  flush=True)
    if log:
        print(f"  N={n} d={degree} P={p} mixop speedup sparse/dense: "
              f"{us['dense'] / us['sparse']:.2f}x", flush=True)
    return recs


def run_payload(rounds: int = 16, n: int = 1024, degree: int = 6, chunk: int = 32,
                budget: float = 0.01, repeats: int = 3, log: bool = True):
    """Part 4: payload-form compressed sharing vs the dense-mask oracle at
    the paper's sparsified emulation scale (N=1024, d=6, budget=0.01,
    chunk=32, static d-regular overlay).

    Each case holds *everything but the aggregation form* fixed: payload
    'on' and 'off' engines run the same coordinate selection and produce
    the same trajectories (property-tested in tests/test_sparse_mixing.py);
    the measured axis is O(N·d·k) gather+scatter over (N, k) payloads vs
    two full O(N·d·P) apply_W passes over scattered (N, P) masks, plus the
    sharing stage's staged message bytes (``share_stage_bytes``).

    The *gate* case is randomk with the strided sampler on pure
    consensus-gossip rounds (local_steps=0): selection is O(N), the
    receive is the windowed-scatter fast path, so the round is
    sharing-dominated and the aggregation form is what's measured —
    payload ≥ 3x dense-mask rounds/s and staging ≥ 10x less (median).
    The topk case (selection = a lax.top_k sort over the full (N, P)
    state, shared by both paths and O(N·P·log) on CPU) is recorded
    alongside, un-gated: its e2e ratio is selection-diluted on CPU; the
    histogram-threshold selector (kernels/sparsify.topk_threshold_rows)
    is the TPU answer to that term.  P=1024 (P_PAYLOAD) so the sharing
    stage dominates the fixed dispatch cost, mirroring real models where
    P ≫ N·d.
    """
    recs = []
    if rounds <= 0:
        return recs
    cases = {
        "randomk-strided": dict(sharing="randomk", randk_sampler="strided",
                                local_steps=0),
        "topk": dict(sharing="topk"),
    }
    for case, case_kw in cases.items():
        engines = {}
        for payload in ("off", "on"):
            eng = _engine(n, chunk, topology="regular", degree=degree,
                          p_dim=P_PAYLOAD, budget=budget, payload=payload,
                          **case_kw)
            eng.run(rounds=rounds, log=False)  # warm-up compiles every scan length
            engines[payload] = eng
        # interleave timed repeats so box load hits both paths equally
        samples = {"off": [], "on": []}
        for _ in range(repeats):
            for payload, eng in engines.items():
                t0 = time.time()
                eng.run(rounds=rounds, log=False)
                samples[payload].append(rounds / (time.time() - t0))
        rps = {}
        for payload, eng in engines.items():
            stats = _rps_stats(samples[payload])
            rps[payload] = stats["rounds_per_s"]
            recs.append({
                "name": f"N{n}-d{degree}-{case}-b{budget}-payload-{payload}",
                "n_nodes": n, "degree": degree, "case": case,
                "sharing": case_kw["sharing"], "budget": budget,
                "payload": payload, "chunk": chunk, "rounds": rounds, **stats,
                "wire_dtype": eng.wire_dtype,
                "share_stage_bytes": eng.share_stage_bytes,
            })
            if log:
                print(f"  N={n} d={degree} {case:14s} b={budget} "
                      f"payload={payload:3s} {rps[payload]:8.1f} rounds/s  "
                      f"share_stage={eng.share_stage_bytes / 1e3:.1f}KB",
                      flush=True)
        if log:
            stage_ratio = (engines["off"].share_stage_bytes
                           / max(engines["on"].share_stage_bytes, 1))
            print(f"  N={n} d={degree} {case:14s} speedup payload/dense: "
                  f"{rps['on'] / rps['off']:.2f}x  stage-bytes ratio: "
                  f"{stage_ratio:.0f}x", flush=True)
    return recs


def run_async(rounds: int = 96, n: int = 1024, degree: int = 6, chunk: int = 32,
              base_compute_s: float = 0.05, straggler_factor: float = 10.0,
              straggler_frac: float = 0.1, targets=(0.2, 0.3),
              log: bool = True):
    """Part 5: event-driven async gossip (semantics='async') vs the
    synchronous round barrier at the paper's 1000+-node scale, under a
    10x-straggler compute-time distribution (10% of nodes at 10x the base
    50 ms — ``network.straggler_compute_times``), network='lan'.

    The workload is the *gradient-work-limited* regime the AD-PSGD claim
    lives in: an MLP classification task (benchmarks/common.model_fns)
    where accuracy is bought with local SGD steps over many rounds — not
    the consensus micro-benchmark of parts 1-4, whose loss drops mostly
    through init-variance averaging and would hide the work-rate
    difference.  Sync pays the straggler at every round barrier (round
    time = max over nodes, so every node takes 1 gradient step per ~0.5 s
    of simulated time); async fires event cohorts on the virtual clock,
    so the fast 90% of nodes take ~10x more steps per simulated second,
    gossiping against possibly-stale straggler rows.

    The headline metric is **simulated wall-clock until the mean node
    accuracy reaches a fixed target** (10-class task, random = 0.10;
    targets 0.20 and 0.30).  The *gate* is the 0.30 target: async must
    reach it in <= 0.5x sync's simulated time (observed ~8-9x lower).
    Both trajectories are deterministic functions of the seed, so no
    repeats are needed (the measurement is virtual time, not wall time).
    Async's per-node virtual-clock spread, staleness, and event counts
    are recorded alongside (scheduler extra metrics).
    """
    from repro.data import NodeBatcher, make_dataset, sharding_partition
    from repro.optim import make_optimizer as _mk_opt

    from benchmarks.common import model_fns

    recs = []
    if rounds <= 0:
        return recs
    ds = make_dataset("cifar10", n_train=8 * n, n_test=256, sigma=4.0, seed=7)
    gate_target = max(targets)
    engines = {}
    for sem in ("sync", "async"):
        parts = sharding_partition(ds.train_y, n, 2, seed=0)
        batcher = NodeBatcher(ds.train_x, ds.train_y, parts, 8, seed=0)
        init, loss, acc = model_fns("mlp", width=4)
        dl = DLConfig(n_nodes=n, topology="regular", degree=degree,
                      local_steps=2, batch_size=8, chunk_rounds=chunk,
                      eval_every=8, semantics=sem, network="lan",
                      compute_time_s=base_compute_s,
                      straggler_factor=straggler_factor,
                      straggler_frac=straggler_frac)
        eng = RoundEngine(dl, init, loss, acc, _mk_opt("sgd", 0.05), batcher)
        eng.run(rounds=rounds, log=False)
        engines[sem] = eng

    def time_to(hist, target):
        for rec in hist:
            if rec["acc_mean"] >= target:
                return rec["sim_time_s"]
        return None

    times = {}
    for sem, eng in engines.items():
        tt = {t: time_to(eng.history, t) for t in targets}
        times[sem] = tt
        last = eng.history[-1]
        rec = {
            "name": f"N{n}-d{degree}-{sem}-straggler{straggler_factor:g}x",
            "n_nodes": n, "degree": degree, "semantics": sem,
            "chunk": chunk, "rounds": rounds, "workload": "mlp",
            "compute_time_s": base_compute_s,
            "straggler_factor": straggler_factor,
            "straggler_frac": straggler_frac,
            "sim_time_to_acc_s": {f"{t:g}": v for t, v in tt.items()},
            "sim_time_total_s": eng.sim_time_s,
            "final_acc": last["acc_mean"],
        }
        for k in ("events_total", "events_min", "events_max", "vclock_min_s",
                  "vclock_median_s", "vclock_max_s", "staleness_mean",
                  "staleness_max"):
            if k in last:
                rec[k] = last[k]
        recs.append(rec)
        if log:
            fmt = ", ".join(
                f"acc{t:g} {v:.1f}s" if v is not None else f"acc{t:g} -"
                for t, v in tt.items()
            )
            print(f"  N={n} d={degree} {sem:6s} sim-to-target: {fmt}  "
                  f"(total {eng.sim_time_s:.1f}s, final acc "
                  f"{last['acc_mean']:.4f})", flush=True)
    speedups = {
        t: times["sync"][t] / times["async"][t]
        for t in targets
        if times["sync"].get(t) and times["async"].get(t)
    }
    gate = speedups.get(gate_target)
    recs.append({
        "name": f"N{n}-d{degree}-async-vs-sync-gate",
        "sim_speedup_to_target": {f"{t:g}": s for t, s in speedups.items()},
        "gate_target_acc": gate_target,
        "gate_min_speedup": 2.0,
        "gate_pass": bool(gate is not None and gate >= 2.0),
    })
    if log:
        fmt = ", ".join(f"acc{t:g} {s:.2f}x" for t, s in speedups.items())
        print(f"  N={n} d={degree} async/sync simulated-time speedup to "
              f"fixed accuracy: {fmt} (gate: acc{gate_target:g} >= 2x)",
              flush=True)
    return recs


def run_sharded(rounds: int = 12, n: int = 1024, degree: int = 6, chunk: int = 32,
                repeats: int = 3, devices: int = 8, log: bool = True):
    """Part 3: node-sharded vs single-device RoundEngine at the paper's
    1000+-node scale (N=1024, d=6, chunk=32, static d-regular overlay).

    The sharded engine runs the scanned chunk under shard_map over
    ``devices`` devices (CPU: emulated via
    ``XLA_FLAGS=--xla_force_host_platform_device_count``), in both
    distributed-gossip lowerings: 'gather' (all-gather + local neighbor
    gather) and 'ppermute' (slot-rebalanced per-offset collective_permute
    — the interconnect-native path; on CPU every emulated collective is a
    host rendezvous, so this records honest emulation numbers, not the TPU
    story).  The single-device baseline runs in the *same* process so both
    see the same host contention.

    When the current process doesn't have enough devices the section
    re-executes itself in a subprocess with the XLA flag set (device count
    locks at first jax init), so a plain ``python benchmarks/bench_engine.py``
    still records the sharded entries.
    """
    recs = []
    if rounds <= 0:
        return recs
    if jax.device_count() < devices:
        return _run_sharded_subprocess(rounds, n, degree, chunk, repeats, devices, log)
    cases = {
        "single": dict(),
        f"sharded{devices}-gather": dict(shard_devices=devices, shard_backend="gather"),
        f"sharded{devices}-ppermute": dict(shard_devices=devices, shard_backend="ppermute"),
    }
    engines = {}
    for case, kw in cases.items():
        eng = _engine(n, chunk, topology="regular", degree=degree,
                      p_dim=P_MIXING, **kw)
        eng.run(rounds=rounds, log=False)  # warm-up compiles every scan length
        engines[case] = eng
    samples = {case: [] for case in cases}
    for _ in range(repeats):
        for case, eng in engines.items():
            t0 = time.time()
            eng.run(rounds=rounds, log=False)
            samples[case].append(rounds / (time.time() - t0))
    rps = {}
    for case, eng in engines.items():
        stats = _rps_stats(samples[case])
        rps[case] = stats["rounds_per_s"]
        recs.append({
            "name": f"N{n}-d{degree}-{case}", "n_nodes": n, "degree": degree,
            "topology": "regular", "chunk": chunk, "rounds": rounds,
            "n_devices": devices if case != "single" else 1, **stats,
        })
        if log:
            print(f"  N={n} d={degree} {case:18s} {rps[case]:8.1f} rounds/s",
                  flush=True)
    if log:
        for case in rps:
            if case != "single":
                print(f"  N={n} d={degree} speedup {case}/single: "
                      f"{rps[case] / rps['single']:.2f}x", flush=True)
    return recs


def _run_sharded_subprocess(rounds, n, degree, chunk, repeats, devices, log):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={devices}"
    ).strip()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), root, env.get("PYTHONPATH", "")]
    ).rstrip(os.pathsep)
    cmd = [
        sys.executable, os.path.abspath(__file__), "--_sharded-worker",
        "--sharded-rounds", str(rounds), "--sparse-nodes", str(n),
        "--sharded-degree", str(degree), "--sharded-repeats", str(repeats),
        "--sharded-devices", str(devices), "--sharded-chunk", str(chunk),
    ]
    p = subprocess.run(cmd, capture_output=True, text=True, env=env,
                       cwd=root, timeout=3600)
    recs = []
    for line in p.stdout.splitlines():
        if line.startswith("SHARDED_JSON:"):
            recs = json.loads(line[len("SHARDED_JSON:"):])
        elif log:
            print(line, flush=True)
    if not recs:
        raise RuntimeError(
            f"sharded bench subprocess produced no records:\n{p.stdout}\n{p.stderr}"
        )
    return recs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=64)
    ap.add_argument("--nodes", type=int, nargs="+", default=[64, 256])
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--sparse-rounds", type=int, default=32,
                    help="rounds for the N=1024 sparse-vs-dense section; 0 skips it")
    ap.add_argument("--sparse-nodes", type=int, default=1024)
    ap.add_argument("--sparse-repeats", type=int, default=3)
    ap.add_argument("--payload-rounds", type=int, default=16,
                    help="rounds for the N=1024 payload-vs-dense section; 0 skips it")
    ap.add_argument("--payload-budget", type=float, default=0.01)
    ap.add_argument("--payload-repeats", type=int, default=3)
    ap.add_argument("--async-rounds", type=int, default=96,
                    help="rounds/cohorts for the N=1024 async-vs-sync "
                         "straggler section (sync needs ~50 rounds to cross "
                         "the acc-0.3 gate target); 0 skips it")
    ap.add_argument("--async-straggler-factor", type=float, default=10.0)
    ap.add_argument("--sharded-rounds", type=int, default=12,
                    help="rounds for the N=1024 sharded-vs-single section; 0 skips it")
    ap.add_argument("--sharded-degree", type=int, default=6)
    ap.add_argument("--sharded-repeats", type=int, default=3)
    ap.add_argument("--sharded-devices", type=int, default=8)
    ap.add_argument("--sharded-chunk", type=int, default=32)
    ap.add_argument("--_sharded-worker", action="store_true",
                    help=argparse.SUPPRESS)
    args = ap.parse_args()
    if getattr(args, "_sharded_worker"):
        if jax.device_count() < args.sharded_devices:
            # never re-spawn from the worker: the parent already set the
            # XLA flag; if it didn't take (non-CPU backend), fail loudly
            raise RuntimeError(
                f"sharded worker sees {jax.device_count()} devices, needs "
                f"{args.sharded_devices}; --xla_force_host_platform_device_count "
                "only applies to the CPU backend (set JAX_PLATFORMS=cpu)"
            )
        recs = run_sharded(args.sharded_rounds, n=args.sparse_nodes,
                           degree=args.sharded_degree, chunk=args.sharded_chunk,
                           repeats=args.sharded_repeats,
                           devices=args.sharded_devices)
        print("SHARDED_JSON:" + json.dumps(recs), flush=True)
        return
    recs = run(args.rounds, tuple(args.nodes), repeats=args.repeats, save=False)
    if args.sparse_rounds > 0:
        recs += run_sparse(args.sparse_rounds, n=args.sparse_nodes,
                           repeats=args.sparse_repeats)
    if args.payload_rounds > 0:
        recs += run_payload(args.payload_rounds, n=args.sparse_nodes,
                            budget=args.payload_budget,
                            repeats=args.payload_repeats)
    if args.async_rounds > 0:
        recs += run_async(args.async_rounds, n=args.sparse_nodes,
                          straggler_factor=args.async_straggler_factor)
    if args.sharded_rounds > 0:
        recs += run_sharded(args.sharded_rounds, n=args.sparse_nodes,
                            degree=args.sharded_degree,
                            chunk=args.sharded_chunk,
                            repeats=args.sharded_repeats,
                            devices=args.sharded_devices)
    # one write, after all sections; section-only smokes (--rounds 0, as in
    # CI) record separately so they never clobber the dispatch-gate file
    if args.rounds > 0:
        bench = "bench_engine"
    elif args.sparse_rounds > 0:
        bench = "bench_engine_sparse"
    elif args.payload_rounds > 0:
        bench = "bench_engine_payload"
    elif args.async_rounds > 0:
        bench = "bench_engine_async"
    else:
        bench = "bench_engine_sharded"
    if recs:
        save_results(bench, recs)
    print("\nname,rounds_per_s|op_us|sim_s")
    for r in recs:
        v = r.get("rounds_per_s",
                  r.get("op_us", r.get("sim_time_total_s")))
        if isinstance(v, (int, float)):
            print(f"{r['name']},{v:.1f}")


if __name__ == "__main__":
    main()
