"""Paper Fig. 4: sparsification (random sampling, CHOCO-SGD, TopK) vs full
sharing at a 10% communication budget, 5-regular topology, non-IID.

Paper claim validated: under non-IID at scale, sparsification converges
worse than full sharing for the same number of rounds."""
from __future__ import annotations

import argparse

from repro.core import DLConfig

from benchmarks.common import dl_experiment, save_results


def run(nodes: int = 32, rounds: int = 120, budget: float = 0.1, model: str = "mlp",
        seeds: int = 1, log: bool = True):
    recs = []
    for name, sharing in [
        ("full-sharing", "full"),
        ("random-sampling", "randomk"),
        ("topk", "topk"),
        ("choco-sgd", "choco"),
    ]:
        dl = DLConfig(n_nodes=nodes, topology="regular", degree=5, rounds=rounds,
                      eval_every=max(rounds // 12, 1), local_steps=4, batch_size=8,
                      sharing=sharing, budget=budget)
        recs.append(dl_experiment(name, dl, model=model, seeds=seeds, log=log))
    save_results("bench_sparsification", recs)
    return recs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=32)
    ap.add_argument("--rounds", type=int, default=120)
    ap.add_argument("--budget", type=float, default=0.1)
    ap.add_argument("--model", default="mlp", choices=["mlp", "cnn"])
    ap.add_argument("--seeds", type=int, default=1)
    args = ap.parse_args()
    recs = run(args.nodes, args.rounds, args.budget, args.model, args.seeds)
    print("\nname,acc,bytes_per_node_MB")
    for r in recs:
        print(f"{r['name']},{r['acc_mean']:.4f},{r['bytes_per_node']/1e6:.1f}")


if __name__ == "__main__":
    main()
