"""Paper Fig. 5: secure aggregation vs plain D-PSGD on two datasets
(CIFAR-10-like and CelebA-like), 5-regular graph, 48 nodes in the paper
(CLI-tunable here).

Paper claims validated: comparable accuracy (small precision loss) at
~3% extra communication."""
from __future__ import annotations

import argparse

from repro.core import DLConfig

from benchmarks.common import dl_experiment, save_results


def run(nodes: int = 16, rounds: int = 80, model: str = "mlp", seeds: int = 1,
        log: bool = True):
    recs = []
    for dataset in ("cifar10", "celeba"):
        for name, secure in (("d-psgd", False), ("secure-agg", True)):
            dl = DLConfig(n_nodes=nodes, topology="regular", degree=4, rounds=rounds,
                          eval_every=max(rounds // 6, 1), local_steps=4, batch_size=8,
                          secure=secure)
            recs.append(
                dl_experiment(f"{dataset}/{name}", dl, dataset=dataset, model=model,
                              seeds=seeds, log=log)
            )
    save_results("bench_secure_agg", recs)
    return recs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=16)
    ap.add_argument("--rounds", type=int, default=80)
    ap.add_argument("--model", default="mlp", choices=["mlp", "cnn"])
    ap.add_argument("--seeds", type=int, default=1)
    args = ap.parse_args()
    recs = run(args.nodes, args.rounds, args.model, args.seeds)
    print("\nname,acc,bytes_per_node_MB")
    for r in recs:
        print(f"{r['name']},{r['acc_mean']:.4f},{r['bytes_per_node']/1e6:.1f}")


if __name__ == "__main__":
    main()
