"""Kernel microbenchmarks: interpret-mode correctness cost is meaningless
for wall-time, so this bench reports (a) the pure-jnp oracle wall time on
CPU as a stand-in and (b) the kernel's structural roofline: bytes touched,
FLOPs, arithmetic intensity — the numbers that matter on the TPU target."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref


def _time(fn, *args, reps=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6  # us


def run():
    rows = []
    # gossip_mix: K=6 neighbors x 4M params
    K, M = 6, 4_000_000
    nb = jax.random.normal(jax.random.key(0), (K, M))
    w = jnp.full((K,), 1.0 / K)
    us = _time(jax.jit(ref.gossip_mix_ref), nb, w)
    byts = (K + 1) * M * 4
    rows.append(("gossip_mix[6x4M]", us, f"bytes={byts/1e6:.0f}MB AI={2*K*M/byts:.2f}"))

    # quantize 4M
    x = jax.random.normal(jax.random.key(1), (64, 65536))
    us = _time(jax.jit(ref.quantize_ref), x)
    rows.append(("quantize_int8[4M]", us, f"bytes={x.size*5/1e6:.0f}MB"))

    # secure mask K=5 x 4M
    bits = jax.random.bits(jax.random.key(2), (5, M), jnp.uint32)
    signs = jnp.ones((5,))
    xv = jax.random.normal(jax.random.key(3), (M,))
    us = _time(jax.jit(ref.secure_mask_apply_ref), xv, bits, signs, 1.0)
    rows.append(("secure_mask[5x4M]", us, f"bytes={(6*M*4)/1e6:.0f}MB"))

    # ssd chunk: G=32 chunks, L=128, H=8, P=64, N=128
    G, L, H, P, N = 32, 128, 8, 64, 128
    xdt = jax.random.normal(jax.random.key(4), (G, L, H, P)) * 0.1
    Bc = jax.random.normal(jax.random.key(5), (G, L, N))
    Cc = jax.random.normal(jax.random.key(6), (G, L, N))
    cum = -jnp.cumsum(jax.random.uniform(jax.random.key(7), (G, L, H)) * 0.1, 1)
    flops = G * H * (2 * L * L * N + 2 * L * L * P + 2 * L * N * P)

    def ssd_all(xdt, Bc, Cc, cum):
        return jax.vmap(ref.ssd_chunk_ref)(xdt, Bc, Cc, cum)

    us = _time(jax.jit(ssd_all), xdt, Bc, Cc, cum)
    rows.append(("ssd_chunk[32x128]", us, f"GFLOP={flops/1e9:.2f}"))

    # swa attention S=4096 W=1024 D=64 BH=8
    BH, S, W, D = 8, 4096, 1024, 64
    q = jax.random.normal(jax.random.key(8), (BH, S, D))
    k = jax.random.normal(jax.random.key(9), (BH, S, D))
    v = jax.random.normal(jax.random.key(10), (BH, S, D))

    def swa_all(q, k, v):
        return jax.vmap(lambda a, b, c: ref.swa_attention_ref(a, b, c, W))(q, k, v)

    us = _time(jax.jit(swa_all), q, k, v, reps=2)
    flops = BH * 4 * S * W * D
    rows.append(("swa_attn[4k,w1k]", us, f"GFLOP={flops/1e9:.2f} (O(S*W) vs O(S^2)={S/W:.0f}x)"))
    return rows


def main():
    for name, us, derived in run():
        print(f"{name},{us:.0f},{derived}")


if __name__ == "__main__":
    main()
