"""Benchmark entry point: one function per paper table/figure.

``python -m benchmarks.run``         — quick CI-scale pass of every bench
``python -m benchmarks.run --full``  — paper-scale settings (slow; the
                                       EXPERIMENTS.md numbers)

Prints ``name,us_per_call,derived`` CSV per bench plus the per-figure
summary lines.
"""
from __future__ import annotations

import argparse
import sys
import time


def _line(name, us, derived):
    print(f"{name},{us:.0f},{derived}", flush=True)


def bench_fig3_topologies(full: bool) -> None:
    from benchmarks.bench_topologies import run

    t0 = time.time()
    recs = run(nodes=32 if full else 12, rounds=150 if full else 12, log=full)
    us = (time.time() - t0) * 1e6 / max(recs[0]["history"][-1]["round"] + 1, 1)
    acc = {r["name"]: r["acc_mean"] for r in recs}
    byt = {r["name"]: r["bytes_per_node"] for r in recs}
    _line(
        "fig3_topologies", us,
        f"acc ring={acc['ring']:.3f} 5reg={acc['5-regular']:.3f} "
        f"fully={acc['fully']:.3f} dyn={acc['dynamic-5-regular']:.3f}; "
        f"bytes fully/dyn={byt['fully'] / max(byt['dynamic-5-regular'], 1):.1f}x",
    )


def bench_fig4_sparsification(full: bool) -> None:
    from benchmarks.bench_sparsification import run

    t0 = time.time()
    recs = run(nodes=32 if full else 12, rounds=150 if full else 12, log=full)
    us = (time.time() - t0) * 1e6 / len(recs)
    acc = {r["name"]: r["acc_mean"] for r in recs}
    _line(
        "fig4_sparsification", us,
        f"acc full={acc['full-sharing']:.3f} randk={acc['random-sampling']:.3f} "
        f"topk={acc['topk']:.3f} choco={acc['choco-sgd']:.3f}",
    )


def bench_fig5_secure_agg(full: bool) -> None:
    from benchmarks.bench_secure_agg import run

    t0 = time.time()
    recs = run(nodes=16 if full else 8, rounds=80 if full else 8, log=full)
    us = (time.time() - t0) * 1e6 / len(recs)
    acc = {r["name"]: r["acc_mean"] for r in recs}
    byt = {r["name"]: r["bytes_per_node"] for r in recs}
    _line(
        "fig5_secure_agg", us,
        f"cifar dpsgd={acc['cifar10/d-psgd']:.3f} sec={acc['cifar10/secure-agg']:.3f}; "
        f"overhead={byt['cifar10/secure-agg'] / byt['cifar10/d-psgd'] - 1:.1%}",
    )


def bench_fig6_scalability(full: bool) -> None:
    from benchmarks.bench_scalability import run

    t0 = time.time()
    recs = run(base_nodes=256 if full else 32, rounds=60 if full else 8,
               n_train=16384 if full else 4096, log=full)
    us = (time.time() - t0) * 1e6 / len(recs)
    accs = [f"{r['name']}={r['acc_mean']:.3f}" for r in recs]
    _line("fig6_scalability", us, " ".join(accs))


def bench_kernels(full: bool) -> None:
    from benchmarks.bench_kernels import run

    for name, us, derived in run():
        _line(f"kernel_{name}", us, derived)


def bench_roofline(full: bool) -> None:
    import glob

    from benchmarks.bench_roofline import load

    rows = load(["results/dryrun_sp", "results/dryrun_mp"])
    ok = [r for r in rows if r.get("status") == "ok"]
    skipped = [r for r in rows if r.get("status") == "skipped"]
    if not rows:
        _line("roofline", 0, "no dry-run results yet (run repro.launch.dryrun --all)")
        return
    doms = {}
    for r in ok:
        doms[r["roofline"]["bottleneck"]] = doms.get(r["roofline"]["bottleneck"], 0) + 1
    _line(
        "roofline", sum(r.get("compile_s", 0) for r in ok) * 1e6 / max(len(ok), 1),
        f"{len(ok)} compiled, {len(skipped)} arch-skips; bottlenecks {doms}",
    )


ALL = [
    bench_fig3_topologies,
    bench_fig4_sparsification,
    bench_fig5_secure_agg,
    bench_fig6_scalability,
    bench_kernels,
    bench_roofline,
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale settings")
    ap.add_argument("--only", default=None, help="substring filter on bench name")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for fn in ALL:
        if args.only and args.only not in fn.__name__:
            continue
        try:
            fn(args.full)
        except Exception as e:  # keep the suite running; report the failure
            _line(fn.__name__, 0, f"ERROR: {type(e).__name__}: {e}")
            raise


if __name__ == "__main__":
    main()
