"""Shared harness for the paper-figure benchmarks.

Scale notes: the paper runs 256-1024 node emulations for hundreds of rounds
on 16 Xeon machines; this container is one box, so default benchmark scale
is reduced (nodes/rounds CLI-tunable via --nodes/--rounds/--full) while
keeping the paper's qualitative comparisons intact.  Datasets are seeded
synthetic stand-ins (offline container) — orderings, not absolute
accuracies, are the reproduction target (see EXPERIMENTS.md).
"""
from __future__ import annotations

import os
import time
from typing import Callable, Dict, List

import numpy as np

from repro.core import DLConfig, DecentralizedRunner
from repro.data import NodeBatcher, make_dataset, sharding_partition
from repro.models.api import cross_entropy
from repro.models.cnn import cnn_apply, cnn_init
from repro.models.mlp import mlp_apply, mlp_init
from repro.optim import make_optimizer

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results")


def model_fns(kind: str, width: int = 16):
    if kind == "cnn":
        init = lambda k: cnn_init(k, width=width)
        apply = cnn_apply
    else:
        init = lambda k: mlp_init(k, hidden=8 * width)
        apply = mlp_apply

    def loss_fn(p, x, y):
        return cross_entropy(apply(p, x), y)

    def acc_fn(p, x, y):
        return (apply(p, x).argmax(-1) == y).mean()

    return init, loss_fn, acc_fn


def dl_experiment(
    name: str,
    dl: DLConfig,
    *,
    dataset: str = "cifar10",
    model: str = "mlp",
    width: int = 16,
    lr: float = 0.05,
    n_train: int = 1024,
    n_test: int = 512,
    sigma: float = 4.0,
    log: bool = True,
    seeds: int = 1,
) -> Dict:
    """Run one DL configuration (optionally averaged over seeds) and return
    {name, history, bytes, wall}."""
    runs = []
    for s in range(seeds):
        kw = {} if dataset in ("teacher", "cifar10-hard", "lm") else {"sigma": sigma}
        ds = make_dataset(dataset, n_train=n_train, n_test=n_test, seed=7, **kw)
        parts = sharding_partition(ds.train_y, dl.n_nodes, 2, seed=dl.seed + s)
        batcher = NodeBatcher(ds.train_x, ds.train_y, parts, dl.batch_size, seed=dl.seed + s)
        init, loss, acc = model_fns(model, width)
        import dataclasses

        dls = dataclasses.replace(dl, seed=dl.seed + s)
        r = DecentralizedRunner(dls, init, loss, acc, make_optimizer("sgd", lr), batcher)
        t0 = time.time()
        hist = r.run(log=False)
        runs.append({"history": hist, "bytes": r.bytes_sent, "wall": time.time() - t0,
                     "sim_time": r.sim_time_s})
        if log:
            print(
                f"  [{name} seed{s}] final acc {hist[-1]['acc_mean']:.4f} "
                f"MB/node {r.bytes_sent/1e6:.1f} wall {runs[-1]['wall']:.0f}s",
                flush=True,
            )
    # average final accuracy across seeds
    finals = [r["history"][-1]["acc_mean"] for r in runs]
    out = {
        "name": name,
        "acc_mean": float(np.mean(finals)),
        "acc_ci95": float(1.96 * np.std(finals) / max(np.sqrt(len(finals)), 1)),
        "bytes_per_node": runs[0]["bytes"],
        "sim_time_s": runs[0]["sim_time"],
        "wall_s": float(np.mean([r["wall"] for r in runs])),
        "history": runs[0]["history"],
        "runs": len(runs),
    }
    # fault/retry/recovery counters (core.faults.STAT_KEYS, merged into
    # history records whenever a fault axis is active) are part of the
    # results schema: promote the final record's running totals
    final = runs[0]["history"][-1]
    out.update({k: final[k] for k in (
        "faults_injected", "faults_detected", "faults_survived",
        "faults_recovered", "retry_total", "recovery_bytes",
    ) if k in final})
    return out


def memory_snapshot() -> Dict:
    """Process memory at the time of the call: live device-buffer bytes
    (sum over ``jax.live_arrays()`` — on CPU backends this is host memory
    too, but it is exactly the engine's device-resident working set) plus
    host RSS current/peak from /proc (``resource.getrusage`` fallback).
    -1 marks an unavailable reading."""
    snap = {"device_live_bytes": -1, "host_rss_bytes": -1,
            "host_peak_rss_bytes": -1}
    try:
        import jax

        snap["device_live_bytes"] = int(
            sum(int(a.nbytes) for a in jax.live_arrays())
        )
    except Exception:
        pass
    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith("VmRSS:"):
                    snap["host_rss_bytes"] = int(line.split()[1]) * 1024
                elif line.startswith("VmHWM:"):
                    snap["host_peak_rss_bytes"] = int(line.split()[1]) * 1024
    except OSError:
        pass
    if snap["host_peak_rss_bytes"] < 0:
        try:
            import resource

            snap["host_peak_rss_bytes"] = (
                resource.getrusage(resource.RUSAGE_SELF).ru_maxrss * 1024
            )
        except Exception:
            pass
    return snap


def save_results(bench: str, records: List[Dict]):
    """Write one bench's records plus a trailing ``_memory`` record — every
    bench script inherits peak/live memory capture in its saved JSON, which
    is what makes bounded-memory gates recorded, inspectable quantities.
    Atomic (temp + ``os.replace``): a crashed or killed bench process can
    never leave a truncated ``results/*.json`` behind."""
    from repro.utils.io import atomic_write_json

    path = os.path.join(RESULTS_DIR, f"{bench}.json")
    records = list(records) + [{"name": "_memory", **memory_snapshot()}]
    return atomic_write_json(path, records)
