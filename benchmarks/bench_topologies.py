"""Paper Fig. 3: DL across ring / 5-regular / fully-connected / dynamic
5-regular topologies — accuracy per round, wall-clock, cumulative bytes.

Paper claims validated: (a) fully > regular > ring for equal rounds;
(b) dynamic 5-regular ~ fully at a fraction of the bytes (paper: 51x)."""
from __future__ import annotations

import argparse

from repro.core import DLConfig

from benchmarks.common import dl_experiment, save_results


def run(nodes: int = 32, rounds: int = 120, model: str = "mlp", seeds: int = 1,
        log: bool = True):
    recs = []
    for name, topo, deg in [
        ("ring", "ring", 2),
        ("5-regular", "regular", 5),
        ("fully", "fully", 0),
        ("dynamic-5-regular", "dynamic", 5),
    ]:
        dl = DLConfig(n_nodes=nodes, topology=topo, degree=deg, rounds=rounds,
                      eval_every=max(rounds // 12, 1), local_steps=4, batch_size=8)
        recs.append(dl_experiment(name, dl, model=model, seeds=seeds, log=log))
    save_results("bench_topologies", recs)
    return recs


def simulated_times(recs, nodes: int, rounds: int, model_bytes: float,
                    compute_time_s: float = 0.05):
    """Fig. 3b axis: per-config simulated wall-clock on the paper's
    16-machine LAN testbed (core/network.py)."""
    from repro.core.network import paper_testbed
    from repro.core.topology import Graph

    net = paper_testbed(nodes)
    graphs = {
        "ring": Graph.ring(nodes),
        "5-regular": Graph.regular_circulant(nodes, 5),
        "fully": Graph.fully_connected(nodes),
        "dynamic-5-regular": Graph.regular_circulant(nodes, 5),
    }
    return {
        name: net.experiment_time(g, model_bytes, compute_time_s, rounds)
        for name, g in graphs.items()
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=32)
    ap.add_argument("--rounds", type=int, default=120)
    ap.add_argument("--model", default="mlp", choices=["mlp", "cnn"])
    ap.add_argument("--seeds", type=int, default=1)
    args = ap.parse_args()
    recs = run(args.nodes, args.rounds, args.model, args.seeds)
    base = next(r for r in recs if r["name"] == "fully")
    model_bytes = base["bytes_per_node"] / args.rounds / max(args.nodes - 1, 1)
    sim = simulated_times(recs, args.nodes, args.rounds, model_bytes)
    print("\nname,acc,bytes_per_node_MB,wall_s,sim_lan_s,bytes_vs_fully")
    for r in recs:
        print(f"{r['name']},{r['acc_mean']:.4f},{r['bytes_per_node']/1e6:.1f},"
              f"{r['wall_s']:.0f},{sim[r['name']]:.1f},"
              f"{base['bytes_per_node']/max(r['bytes_per_node'],1):.1f}x-less")


if __name__ == "__main__":
    main()
