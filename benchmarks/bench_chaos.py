"""Chaos harness for the elastic process backend: kill/rejoin cycles.

The crash-rejoin gate of the robustness PR: run a real K-process
localhost experiment while the supervisor SIGKILLs workers on a schedule
and relaunches each with ``--rejoin`` (epoch bumped).  Every cycle must
heal — checkpoint/donor catch-up, two-phase JOIN handshake, pristine
edge-weight restoration — and the whole run must end indistinguishable
in structure from a fault-free one:

* all rounds complete (no survivor stalls on a corpse or a rejoiner),
* every killed worker rejoins (``workers_rejoined == cycles``),
* counter conservation holds on every worker
  (``detected == still_dead + rejoined``),
* every rejoiner's final row-block matches a survivor's view of it
  **bitwise** (full sharing: the re-admitted peer fed the last barrier),
* final consensus error <= 2x the fault-free run's.

``round_min_s`` floors the round length so the relaunch (a fresh python
+ jax boot, seconds) lands mid-run instead of after the natural ~50ms
rounds have already finished.

    PYTHONPATH=src:. python benchmarks/bench_chaos.py            # 2 cycles
    PYTHONPATH=src:. python benchmarks/bench_chaos.py --smoke    # CI: 1
"""
from __future__ import annotations

import argparse
import time

from repro.core import DLConfig

from benchmarks.common import save_results

WL = {"dataset": "cifar10", "model": "mlp", "width": 1,
      "n_train": 256, "n_test": 128, "lr": 0.05}


def run(nodes: int = 16, workers: int = 4, rounds: int = 48, cycles: int = 2,
        round_min_s: float = 0.4, ckpt_every: int = 4, log: bool = True):
    from repro.runtime import ProcessRunner

    base = dict(n_nodes=nodes, topology="regular", degree=5, rounds=rounds,
                eval_every=max(rounds // 4, 1), backend="processes", seed=11)

    # fault-free reference (no round floor needed: the trajectory is
    # round-indexed, so wall-clock pacing does not change consensus)
    if log:
        print(f"[chaos] fault-free reference: N={nodes} K={workers} "
              f"rounds={rounds}", flush=True)
    ref = ProcessRunner(DLConfig(**base), WL, workers=workers,
                        watchdog_s=120.0)
    ref_hist = ref.run(log=False)
    ref_consensus = ref.consensus_error()

    # chaos run: kill+rejoin one worker per cycle, staggered so each
    # relaunch (a full python+jax boot) lands while rounds remain
    victims = [1 + (2 * c) % (workers - 1) for c in range(cycles)]
    plan = [{"worker": victims[c], "kill_at_round": 3 + 9 * c,
             "rejoin": True} for c in range(cycles)]
    if log:
        print(f"[chaos] plan: {plan} round_min_s={round_min_s}", flush=True)
    r = ProcessRunner(
        DLConfig(**base), WL, workers=workers, watchdog_s=120.0,
        chaos_plan=plan, ckpt_every=ckpt_every, round_min_s=round_min_s,
        dump_view=True, keep_run_dir=True,
    )
    t0 = time.time()
    hist = r.run(log=log)
    wall = time.time() - t0
    consensus = r.consensus_error()
    views = r.verify_rejoin_views()

    gates = {
        "all_rounds": bool(hist and hist[-1]["round"] == rounds - 1),
        "all_rejoined": r.workers_rejoined == cycles,
        "conservation": bool(r.conservation["ok"]),
        "bitwise_views": bool(views) and all(views.values()),
        "consensus_2x": consensus <= 2.0 * ref_consensus + 1e-9,
    }
    rec = {
        "name": f"chaos-N{nodes}-K{workers}-{cycles}cycles",
        "nodes": nodes, "workers": workers, "rounds": rounds,
        "cycles": cycles, "round_min_s": round_min_s,
        "chaos_plan": plan,
        "kill_events": r.kill_events,
        "workers_rejoined": r.workers_rejoined,
        "counters": r.counters,
        "conservation": r.conservation,
        "rejoin_views_bitwise": {str(k): bool(v) for k, v in views.items()},
        "catchup": {
            str(w): {"source": res.get("catchup_source"),
                     "start_round": res.get("start_round"),
                     "bytes": res["counters"].get("catchup_bytes", 0)}
            for w, res in r.worker_results.items() if res.get("rejoined")
        },
        "consensus_error": consensus,
        "consensus_error_fault_free": ref_consensus,
        "final_acc": hist[-1]["acc_mean"] if hist else None,
        "final_acc_fault_free": ref_hist[-1]["acc_mean"] if ref_hist else None,
        "wall_s": wall,
        "gates": gates,
        "pass": all(gates.values()),
    }
    if log:
        print(f"[chaos] rejoined {r.workers_rejoined}/{cycles}, consensus "
              f"{consensus:.4f} vs fault-free {ref_consensus:.4f}, "
              f"views {views}, gates {gates}", flush=True)
    for w, res in r.worker_results.items():
        d = r.conservation["per_worker"][str(w)]
        assert d["detected"] == d["still_dead"] + d["rejoined"], (w, d)
    assert rec["pass"], f"chaos gate failed: {gates}"
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--nodes", type=int, default=16)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=48)
    ap.add_argument("--cycles", type=int, default=2)
    ap.add_argument("--round-min-s", type=float, default=0.4)
    ap.add_argument("--smoke", action="store_true",
                    help="CI: one kill+rejoin cycle, fewer rounds")
    args = ap.parse_args(argv)
    if args.smoke:
        rec = run(rounds=30, cycles=1, round_min_s=0.35)
    else:
        rec = run(args.nodes, args.workers, args.rounds, args.cycles,
                  args.round_min_s)
    save_results("bench_chaos", [rec])
    print(f"[chaos] PASS -> results/bench_chaos.json")


if __name__ == "__main__":
    main()
